//! Compact binary RPC for distributed training, over std TCP.
//!
//! Every message is one length-prefixed frame, CRC32-checked like the
//! checkpoint format (same `checkpoint::format::crc32` polynomial):
//!
//! ```text
//! offset  size  field
//! 0       4     magic  "ALPR" (LE u32)
//! 4       1     opcode (HELLO/LOAD/GATHER/UPDATE/BARRIER/SHUTDOWN/ERR)
//! 5       1     flags  (bit 0 = response)
//! 6       2     seq    (LE u16; responses echo the request's seq)
//! 8       4     len    (LE u32, payload bytes; capped by RpcConfig)
//! 12      len   payload
//! 12+len  4     crc32 over bytes [4, 12+len)  (opcode..payload)
//! ```
//!
//! 16 bytes of overhead per frame. Embedding rows cross the wire in
//! their packed m-bit form plus the f32 Δ aux — the whole point of
//! low-precision training is that this is the cheap representation —
//! and gradients go back as f32 (the paper does not quantize
//! gradients). The frame codec is socket-free ([`encode_frame`] /
//! [`decode_frame`]) so benches and tests can measure and corrupt
//! frames without a connection; [`read_frame`]/[`write_frame`] move
//! them over any `Read`/`Write`.

use std::collections::VecDeque;
use std::io::{ErrorKind, Read as IoRead, Write as IoWrite};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::checkpoint::format::{crc32, put_u32, put_u64, take_u32, take_u64};

/// Frame magic: "ALPR" as a little-endian u32.
pub const MAGIC: u32 = u32::from_le_bytes(*b"ALPR");

/// Wire protocol version, exchanged in HELLO.
pub const PROTO_VERSION: u32 = 1;

/// Header bytes before the payload (magic + opcode + flags + seq + len).
pub const HEADER_BYTES: usize = 12;

/// Total framing overhead (header + trailing CRC32).
pub const FRAME_OVERHEAD: usize = HEADER_BYTES + 4;

/// Response flag: set on every reply, echoing the request's seq.
pub const FLAG_RESPONSE: u8 = 1;

/// RPC opcodes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum Op {
    /// Worker → coordinator registration; reply carries the shard
    /// assignment (JSON: shard, n_shards, table geometry, experiment).
    Hello = 1,
    /// Coordinator → worker: a contiguous chunk of the shard's local
    /// rows (packed bytes + Δ aux), streamed at attach time.
    Load = 2,
    /// Coordinator → worker: global ids → packed rows + Δ aux.
    Gather = 3,
    /// Coordinator → worker: per-row f32 grads + the step counter and
    /// RNG draw that key the stochastic-rounding streams.
    Update = 4,
    /// Epoch / quiesce barrier; reply means the worker is in sync.
    Barrier = 5,
    /// Clean shutdown; the worker acks and exits.
    Shutdown = 6,
    /// Error reply: payload is a UTF-8 message from the remote side.
    Err = 7,
}

impl Op {
    pub fn from_u8(v: u8) -> Option<Op> {
        Some(match v {
            1 => Op::Hello,
            2 => Op::Load,
            3 => Op::Gather,
            4 => Op::Update,
            5 => Op::Barrier,
            6 => Op::Shutdown,
            7 => Op::Err,
            _ => return None,
        })
    }
}

/// Client-side transport knobs (coordinator and worker share these).
#[derive(Clone, Copy, Debug)]
pub struct RpcConfig {
    /// Read timeout per call; a peer silent this long is declared dead.
    pub timeout_ms: u64,
    /// Connection attempts before giving up (workers usually start
    /// before the coordinator's listener is up).
    pub connect_retries: u32,
    /// Delay between connection attempts.
    pub retry_delay_ms: u64,
    /// Largest accepted frame payload; oversized frames are a protocol
    /// error, not an allocation.
    pub max_frame: u64,
    /// How long the coordinator waits for all workers to register.
    pub accept_timeout_ms: u64,
}

impl Default for RpcConfig {
    fn default() -> Self {
        Self {
            timeout_ms: 30_000,
            connect_retries: 40,
            retry_delay_ms: 250,
            max_frame: 64 << 20,
            accept_timeout_ms: 120_000,
        }
    }
}

/// Encode one frame to bytes (socket-free; benches measure `.len()`).
pub fn encode_frame(op: Op, flags: u8, seq: u16, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(FRAME_OVERHEAD + payload.len());
    put_u32(&mut out, MAGIC);
    out.push(op as u8);
    out.push(flags);
    out.extend_from_slice(&seq.to_le_bytes());
    put_u32(&mut out, payload.len() as u32);
    out.extend_from_slice(payload);
    let crc = crc32(&out[4..]);
    put_u32(&mut out, crc);
    out
}

/// Decode one complete frame from bytes; checks magic, length and CRC.
pub fn decode_frame(buf: &[u8]) -> Result<(Op, u8, u16, &[u8])> {
    if buf.len() < FRAME_OVERHEAD {
        bail!("rpc frame truncated: {} bytes", buf.len());
    }
    let magic = u32::from_le_bytes(buf[0..4].try_into().unwrap());
    if magic != MAGIC {
        bail!("rpc frame bad magic {magic:#010x}");
    }
    let len = u32::from_le_bytes(buf[8..12].try_into().unwrap()) as usize;
    if buf.len() != FRAME_OVERHEAD + len {
        bail!(
            "rpc frame length mismatch: header says {len}, have {}",
            buf.len() - FRAME_OVERHEAD
        );
    }
    let body = &buf[4..HEADER_BYTES + len];
    let want =
        u32::from_le_bytes(buf[HEADER_BYTES + len..].try_into().unwrap());
    let got = crc32(body);
    if got != want {
        bail!("rpc frame crc mismatch: got {got:#010x}, want {want:#010x}");
    }
    let op = Op::from_u8(buf[4])
        .with_context(|| format!("rpc frame unknown opcode {}", buf[4]))?;
    let seq = u16::from_le_bytes([buf[6], buf[7]]);
    Ok((op, buf[5], seq, &buf[HEADER_BYTES..HEADER_BYTES + len]))
}

/// Write one frame to a stream.
pub fn write_frame(
    w: &mut impl IoWrite,
    op: Op,
    flags: u8,
    seq: u16,
    payload: &[u8],
) -> Result<()> {
    let frame = encode_frame(op, flags, seq, payload);
    w.write_all(&frame).context("rpc write")?;
    w.flush().context("rpc flush")?;
    Ok(())
}

/// Read one frame from a stream, enforcing the payload cap before
/// allocating.
pub fn read_frame(
    r: &mut impl IoRead,
    max_frame: u64,
) -> Result<(Op, u8, u16, Vec<u8>)> {
    let mut header = [0u8; HEADER_BYTES];
    r.read_exact(&mut header).context("rpc read header")?;
    let magic = u32::from_le_bytes(header[0..4].try_into().unwrap());
    if magic != MAGIC {
        bail!("rpc frame bad magic {magic:#010x}");
    }
    let len = u32::from_le_bytes(header[8..12].try_into().unwrap()) as u64;
    if len > max_frame {
        bail!("rpc frame of {len} bytes exceeds --max-frame {max_frame}");
    }
    let mut rest = vec![0u8; len as usize + 4];
    r.read_exact(&mut rest).context("rpc read payload")?;
    let mut frame = Vec::with_capacity(HEADER_BYTES + rest.len());
    frame.extend_from_slice(&header);
    frame.extend_from_slice(&rest);
    let (op, flags, seq, payload) = decode_frame(&frame)?;
    Ok((op, flags, seq, payload.to_vec()))
}

// ---------------------------------------------------------------------------
// Typed payload codecs. Each message body is flat little-endian, built
// from the same put_/take_ primitives as the checkpoint sections.

fn take_bytes<'a>(src: &'a [u8], pos: &mut usize, n: usize) -> Result<&'a [u8]> {
    if src.len() < *pos + n {
        bail!("rpc payload truncated at byte {}", *pos);
    }
    let out = &src[*pos..*pos + n];
    *pos += n;
    Ok(out)
}

fn put_f32s_raw(out: &mut Vec<u8>, xs: &[f32]) {
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn take_f32s(src: &[u8], pos: &mut usize, n: usize) -> Result<Vec<f32>> {
    let raw = take_bytes(src, pos, n * 4)?;
    Ok(raw
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

fn put_u32s(out: &mut Vec<u8>, xs: &[u32]) {
    for &x in xs {
        put_u32(out, x);
    }
}

fn take_u32s(src: &[u8], pos: &mut usize, n: usize) -> Result<Vec<u32>> {
    let raw = take_bytes(src, pos, n * 4)?;
    Ok(raw
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

/// GATHER request: global ids to fetch. `aux_only` skips the packed
/// rows (used by the pre-save quiesce to mirror the Δ table).
#[derive(Debug, PartialEq)]
pub struct GatherReq {
    pub aux_only: bool,
    pub ids: Vec<u32>,
}

impl GatherReq {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(5 + self.ids.len() * 4);
        out.push(self.aux_only as u8);
        put_u32(&mut out, self.ids.len() as u32);
        put_u32s(&mut out, &self.ids);
        out
    }

    pub fn decode(src: &[u8]) -> Result<GatherReq> {
        let mut pos = 0;
        let aux_only = take_bytes(src, &mut pos, 1)?[0] != 0;
        let count = take_u32(src, &mut pos)? as usize;
        let ids = take_u32s(src, &mut pos, count)?;
        Ok(GatherReq { aux_only, ids })
    }
}

/// GATHER response: packed rows (in request order) + per-row Δ aux.
/// `row_bytes == 0` for aux-only replies and for methods with no
/// packed representation; `aux` is empty for methods with no per-row Δ.
#[derive(Debug, PartialEq)]
pub struct GatherResp {
    pub row_bytes: u32,
    pub rows: Vec<u8>,
    pub aux: Vec<f32>,
}

impl GatherResp {
    pub fn encode(&self) -> Vec<u8> {
        let count = if self.row_bytes == 0 {
            0
        } else {
            (self.rows.len() / self.row_bytes as usize) as u32
        };
        let mut out =
            Vec::with_capacity(12 + self.rows.len() + self.aux.len() * 4);
        put_u32(&mut out, count);
        put_u32(&mut out, self.row_bytes);
        put_u32(&mut out, self.aux.len() as u32);
        out.extend_from_slice(&self.rows);
        put_f32s_raw(&mut out, &self.aux);
        out
    }

    pub fn decode(src: &[u8]) -> Result<GatherResp> {
        let mut pos = 0;
        let count = take_u32(src, &mut pos)? as usize;
        let row_bytes = take_u32(src, &mut pos)?;
        let aux_count = take_u32(src, &mut pos)? as usize;
        let rows = take_bytes(src, &mut pos, count * row_bytes as usize)?
            .to_vec();
        let aux = take_f32s(src, &mut pos, aux_count)?;
        Ok(GatherResp { row_bytes, rows, aux })
    }
}

/// LOAD: a contiguous chunk of the shard's local rows, streamed at
/// attach time (packed bytes + the matching slice of the Δ table).
#[derive(Debug, PartialEq)]
pub struct LoadReq {
    pub start_local: u32,
    pub row_bytes: u32,
    pub rows: Vec<u8>,
    pub aux: Vec<f32>,
}

impl LoadReq {
    pub fn count(&self) -> usize {
        if self.row_bytes == 0 {
            0
        } else {
            self.rows.len() / self.row_bytes as usize
        }
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut out =
            Vec::with_capacity(16 + self.rows.len() + self.aux.len() * 4);
        put_u32(&mut out, self.start_local);
        put_u32(&mut out, self.count() as u32);
        put_u32(&mut out, self.row_bytes);
        put_u32(&mut out, self.aux.len() as u32);
        out.extend_from_slice(&self.rows);
        put_f32s_raw(&mut out, &self.aux);
        out
    }

    pub fn decode(src: &[u8]) -> Result<LoadReq> {
        let mut pos = 0;
        let start_local = take_u32(src, &mut pos)?;
        let count = take_u32(src, &mut pos)? as usize;
        let row_bytes = take_u32(src, &mut pos)?;
        let aux_count = take_u32(src, &mut pos)? as usize;
        let rows = take_bytes(src, &mut pos, count * row_bytes as usize)?
            .to_vec();
        let aux = take_f32s(src, &mut pos, aux_count)?;
        Ok(LoadReq { start_local, row_bytes, rows, aux })
    }
}

/// UPDATE: one training step's gradients for this shard's slice of the
/// batch. `step` and `draw` key the counter-based SR streams
/// (`StreamKey::for_step(draw, step).row_rng(global_id)`), which is
/// what makes a worker's quantization bit-identical to single-process.
/// `hp` is the step's scaled hyperparameters, in fixed order:
/// `[lr_emb, wd_emb, lr_delta, wd_delta, grad_scale, lr_scale]`.
#[derive(Debug, PartialEq)]
pub struct UpdateReq {
    pub step: u64,
    pub draw: u64,
    pub hp: [f32; 6],
    pub ids: Vec<u32>,
    pub grads: Vec<f32>,
    pub d_delta: Vec<f32>,
}

impl UpdateReq {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(
            48 + self.ids.len() * 4
                + self.grads.len() * 4
                + self.d_delta.len() * 4,
        );
        put_u64(&mut out, self.step);
        put_u64(&mut out, self.draw);
        put_f32s_raw(&mut out, &self.hp);
        put_u32(&mut out, self.ids.len() as u32);
        put_u32(&mut out, self.d_delta.len() as u32);
        put_u32s(&mut out, &self.ids);
        put_f32s_raw(&mut out, &self.grads);
        put_f32s_raw(&mut out, &self.d_delta);
        out
    }

    pub fn decode(src: &[u8]) -> Result<UpdateReq> {
        let mut pos = 0;
        let step = take_u64(src, &mut pos)?;
        let draw = take_u64(src, &mut pos)?;
        let hp_v = take_f32s(src, &mut pos, 6)?;
        let hp: [f32; 6] = hp_v.try_into().unwrap();
        let count = take_u32(src, &mut pos)? as usize;
        let aux_count = take_u32(src, &mut pos)? as usize;
        let ids = take_u32s(src, &mut pos, count)?;
        let remaining = src
            .len()
            .checked_sub(pos + aux_count * 4)
            .with_context(|| "rpc update payload truncated")?;
        if remaining % 4 != 0 {
            bail!("rpc update grads not f32-aligned");
        }
        let grads = take_f32s(src, &mut pos, remaining / 4)?;
        let d_delta = take_f32s(src, &mut pos, aux_count)?;
        Ok(UpdateReq { step, draw, hp, ids, grads, d_delta })
    }
}

/// BARRIER kinds: 0 = attach complete (worker arms its step counter),
/// 1 = quiesce (all prior updates applied; safe to snapshot), 2 =
/// epoch boundary.
pub const BARRIER_ATTACHED: u8 = 0;
pub const BARRIER_QUIESCE: u8 = 1;
pub const BARRIER_EPOCH: u8 = 2;

// ---------------------------------------------------------------------------
// Connections.

/// Coordinator-side handle to one worker: sends requests, validates
/// responses (magic, CRC, seq echo, response flag), surfaces remote
/// `Err` frames as local errors naming the worker.
///
/// Requests and responses are decoupled ([`send_request`] /
/// [`recv_response`]) so callers can keep several frames in flight per
/// connection; the link tracks outstanding `(op, seq)` pairs and
/// enforces that responses come back in FIFO order — the worker's
/// serve loop is strictly serial, so any out-of-order or unsolicited
/// seq is a protocol violation, not something to reorder around.
///
/// [`send_request`]: WorkerLink::send_request
/// [`recv_response`]: WorkerLink::recv_response
pub struct WorkerLink {
    stream: TcpStream,
    seq: u16,
    max_frame: u64,
    /// Requests written but not yet answered, in send order.
    pending: VecDeque<(Op, u16)>,
}

impl WorkerLink {
    /// Wrap an accepted connection (coordinator side).
    pub fn from_stream(stream: TcpStream, cfg: &RpcConfig) -> Result<WorkerLink> {
        stream.set_nodelay(true).ok();
        stream
            .set_read_timeout(Some(Duration::from_millis(cfg.timeout_ms)))
            .context("rpc set_read_timeout")?;
        Ok(WorkerLink {
            stream,
            seq: 0,
            max_frame: cfg.max_frame,
            pending: VecDeque::new(),
        })
    }

    /// Dial a coordinator (worker side), retrying while it boots.
    pub fn connect(addr: &str, cfg: &RpcConfig) -> Result<WorkerLink> {
        let mut last_err = None;
        for attempt in 0..cfg.connect_retries.max(1) {
            match TcpStream::connect(addr) {
                Ok(stream) => return WorkerLink::from_stream(stream, cfg),
                Err(e) => {
                    last_err = Some(e);
                    if attempt + 1 < cfg.connect_retries.max(1) {
                        std::thread::sleep(Duration::from_millis(
                            cfg.retry_delay_ms,
                        ));
                    }
                }
            }
        }
        Err(last_err.unwrap()).with_context(|| {
            format!(
                "could not connect to {addr} after {} attempts",
                cfg.connect_retries.max(1)
            )
        })
    }

    pub fn peer_addr(&self) -> Option<SocketAddr> {
        self.stream.peer_addr().ok()
    }

    /// Write one request frame without waiting for the reply; returns
    /// the seq the eventual response must echo. The matching
    /// [`recv_response`](WorkerLink::recv_response) may be issued any
    /// number of sends later — the worker answers in FIFO order.
    pub fn send_request(&mut self, op: Op, payload: &[u8]) -> Result<u16> {
        let seq = self.seq;
        self.seq = self.seq.wrapping_add(1);
        write_frame(&mut self.stream, op, 0, seq, payload)?;
        self.pending.push_back((op, seq));
        Ok(seq)
    }

    /// Requests sent but not yet answered.
    pub fn in_flight(&self) -> usize {
        self.pending.len()
    }

    /// Opcode of the oldest outstanding request — the one the next
    /// [`recv_response`](WorkerLink::recv_response) will answer.
    pub fn next_pending_op(&self) -> Option<Op> {
        self.pending.front().map(|&(op, _)| op)
    }

    /// Read the response for the *oldest* outstanding request and
    /// validate it (response flag, seq echo, opcode match). Responses
    /// arriving for any other seq — reordered, duplicated, or
    /// unsolicited — are protocol errors: the serve loop is serial, so
    /// FIFO is the only legal order.
    pub fn recv_response(&mut self) -> Result<Vec<u8>> {
        let Some((op, seq)) = self.pending.pop_front() else {
            bail!("rpc recv with no request in flight");
        };
        let (rop, rflags, rseq, rpayload) =
            read_frame(&mut self.stream, self.max_frame)?;
        if rop == Op::Err {
            bail!(
                "remote error on {op:?}: {}",
                String::from_utf8_lossy(&rpayload)
            );
        }
        if rflags & FLAG_RESPONSE == 0 {
            bail!("rpc {op:?}: peer sent a request, expected a response");
        }
        if rseq != seq {
            bail!(
                "rpc {op:?}: response seq {rseq} != oldest in-flight seq \
                 {seq} (responses must arrive in FIFO order)"
            );
        }
        if rop != op {
            bail!("rpc {op:?}: response opcode {rop:?} does not match");
        }
        Ok(rpayload)
    }

    /// One request/response round trip. Requires no other requests in
    /// flight (a synchronous call in the middle of a pipelined window
    /// would steal the oldest response).
    pub fn call(&mut self, op: Op, payload: &[u8]) -> Result<Vec<u8>> {
        if !self.pending.is_empty() {
            bail!(
                "rpc {op:?}: synchronous call with {} request(s) still in \
                 flight",
                self.pending.len()
            );
        }
        self.send_request(op, payload)?;
        self.recv_response()
    }

    /// The raw stream (the worker reuses its HELLO connection as the
    /// serve loop's transport).
    pub fn into_stream(self) -> TcpStream {
        self.stream
    }
}

/// The coordinator's registration listener: bound before workers are
/// told to dial in, polled with a deadline so a missing worker is a
/// loud timeout instead of a hang.
pub struct WorkerHub {
    listener: TcpListener,
    cfg: RpcConfig,
}

impl WorkerHub {
    pub fn bind(addr: &str, cfg: RpcConfig) -> Result<WorkerHub> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding worker listener on {addr}"))?;
        listener
            .set_nonblocking(true)
            .context("worker listener set_nonblocking")?;
        Ok(WorkerHub { listener, cfg })
    }

    pub fn cfg(&self) -> &RpcConfig {
        &self.cfg
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        self.listener.local_addr().context("worker listener local_addr")
    }

    /// Accept one worker connection, or time out.
    pub fn accept_worker(&self) -> Result<TcpStream> {
        let deadline = Instant::now()
            + Duration::from_millis(self.cfg.accept_timeout_ms);
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    stream
                        .set_nonblocking(false)
                        .context("worker stream set_nonblocking(false)")?;
                    return Ok(stream);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        bail!(
                            "timed out after {} ms waiting for a worker to \
                             register on {}",
                            self.cfg.accept_timeout_ms,
                            self.local_addr()
                                .map(|a| a.to_string())
                                .unwrap_or_else(|_| "?".into())
                        );
                    }
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) => {
                    return Err(e).context("accepting worker connection")
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip() {
        let payload = b"hello shard".to_vec();
        let frame = encode_frame(Op::Gather, FLAG_RESPONSE, 7, &payload);
        assert_eq!(frame.len(), FRAME_OVERHEAD + payload.len());
        let (op, flags, seq, body) = decode_frame(&frame).unwrap();
        assert_eq!(op, Op::Gather);
        assert_eq!(flags, FLAG_RESPONSE);
        assert_eq!(seq, 7);
        assert_eq!(body, &payload[..]);
    }

    #[test]
    fn corrupt_frames_are_rejected() {
        let frame = encode_frame(Op::Update, 0, 3, b"payload bytes");
        // flip one payload bit -> CRC mismatch
        let mut bad = frame.clone();
        bad[HEADER_BYTES + 2] ^= 0x10;
        let err = decode_frame(&bad).unwrap_err().to_string();
        assert!(err.contains("crc"), "{err}");
        // flip a header bit (opcode is covered by the CRC too)
        let mut bad = frame.clone();
        bad[4] ^= 1;
        assert!(decode_frame(&bad).is_err());
        // bad magic
        let mut bad = frame.clone();
        bad[0] ^= 0xFF;
        let err = decode_frame(&bad).unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");
        // truncation
        assert!(decode_frame(&frame[..frame.len() - 1]).is_err());
    }

    #[test]
    fn read_frame_enforces_cap() {
        let frame = encode_frame(Op::Load, 0, 0, &[0u8; 256]);
        let mut cursor = &frame[..];
        let err = read_frame(&mut cursor, 64).unwrap_err().to_string();
        assert!(err.contains("max-frame"), "{err}");
        let mut cursor = &frame[..];
        let (op, _, _, body) = read_frame(&mut cursor, 1024).unwrap();
        assert_eq!(op, Op::Load);
        assert_eq!(body.len(), 256);
    }

    #[test]
    fn gather_codec_roundtrip() {
        let req = GatherReq { aux_only: false, ids: vec![3, 99, 7] };
        assert_eq!(GatherReq::decode(&req.encode()).unwrap(), req);
        let resp = GatherResp {
            row_bytes: 4,
            rows: vec![1, 2, 3, 4, 5, 6, 7, 8],
            aux: vec![0.5, 0.25],
        };
        assert_eq!(GatherResp::decode(&resp.encode()).unwrap(), resp);
        // aux-only: no rows, row_bytes 0
        let resp = GatherResp {
            row_bytes: 0,
            rows: Vec::new(),
            aux: vec![1.0, 2.0, 3.0],
        };
        assert_eq!(GatherResp::decode(&resp.encode()).unwrap(), resp);
    }

    #[test]
    fn load_codec_roundtrip() {
        let req = LoadReq {
            start_local: 17,
            row_bytes: 3,
            rows: vec![9, 8, 7, 6, 5, 4],
            aux: vec![0.125, 0.5],
        };
        assert_eq!(req.count(), 2);
        assert_eq!(LoadReq::decode(&req.encode()).unwrap(), req);
    }

    #[test]
    fn update_codec_roundtrip() {
        let req = UpdateReq {
            step: 41,
            draw: 0xDEAD_BEEF_CAFE_F00D,
            hp: [0.01, 5e-8, 2e-5, 5e-8, 1.0, 0.1],
            ids: vec![2, 10, 6],
            grads: vec![0.1; 3 * 4],
            d_delta: vec![0.5, -0.25, 0.0],
        };
        assert_eq!(UpdateReq::decode(&req.encode()).unwrap(), req);
        // LPT sends no delta grads
        let req = UpdateReq {
            step: 0,
            draw: 1,
            hp: [0.0; 6],
            ids: vec![1],
            grads: vec![0.0; 4],
            d_delta: Vec::new(),
        };
        assert_eq!(UpdateReq::decode(&req.encode()).unwrap(), req);
    }

    #[test]
    fn loopback_call_roundtrip() {
        let cfg = RpcConfig {
            accept_timeout_ms: 5_000,
            timeout_ms: 5_000,
            ..RpcConfig::default()
        };
        let hub = WorkerHub::bind("127.0.0.1:0", cfg).unwrap();
        let addr = hub.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let stream = hub.accept_worker().unwrap();
            let mut link = WorkerLink::from_stream(stream, &cfg).unwrap();
            // serve exactly one request, echoing the payload back
            let (op, flags, seq, payload) =
                read_frame(&mut link.stream, cfg.max_frame).unwrap();
            assert_eq!(flags & FLAG_RESPONSE, 0);
            write_frame(&mut link.stream, op, FLAG_RESPONSE, seq, &payload)
                .unwrap();
        });
        let mut client = WorkerLink::connect(&addr, &cfg).unwrap();
        let reply = client.call(Op::Barrier, &[BARRIER_EPOCH]).unwrap();
        assert_eq!(reply, vec![BARRIER_EPOCH]);
        server.join().unwrap();
    }

    #[test]
    fn pipelined_send_recv_matches_fifo() {
        let cfg = RpcConfig {
            accept_timeout_ms: 5_000,
            timeout_ms: 5_000,
            ..RpcConfig::default()
        };
        let hub = WorkerHub::bind("127.0.0.1:0", cfg).unwrap();
        let addr = hub.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let stream = hub.accept_worker().unwrap();
            let mut link = WorkerLink::from_stream(stream, &cfg).unwrap();
            // serve three back-to-back requests in arrival order, like
            // the worker's serial loop
            for _ in 0..3 {
                let (op, _, seq, payload) =
                    read_frame(&mut link.stream, cfg.max_frame).unwrap();
                write_frame(&mut link.stream, op, FLAG_RESPONSE, seq, &payload)
                    .unwrap();
            }
        });
        let mut client = WorkerLink::connect(&addr, &cfg).unwrap();
        // window of three outstanding requests on one connection
        client.send_request(Op::Gather, b"a").unwrap();
        client.send_request(Op::Update, b"bb").unwrap();
        client.send_request(Op::Gather, b"ccc").unwrap();
        assert_eq!(client.in_flight(), 3);
        assert_eq!(client.recv_response().unwrap(), b"a");
        assert_eq!(client.recv_response().unwrap(), b"bb");
        assert_eq!(client.recv_response().unwrap(), b"ccc");
        assert_eq!(client.in_flight(), 0);
        server.join().unwrap();
    }

    #[test]
    fn out_of_order_or_unsolicited_responses_are_rejected() {
        let cfg = RpcConfig {
            accept_timeout_ms: 5_000,
            timeout_ms: 5_000,
            ..RpcConfig::default()
        };
        let hub = WorkerHub::bind("127.0.0.1:0", cfg).unwrap();
        let addr = hub.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let stream = hub.accept_worker().unwrap();
            let mut link = WorkerLink::from_stream(stream, &cfg).unwrap();
            // answer the two requests in the WRONG order
            let (op0, _, seq0, p0) =
                read_frame(&mut link.stream, cfg.max_frame).unwrap();
            let (op1, _, seq1, p1) =
                read_frame(&mut link.stream, cfg.max_frame).unwrap();
            write_frame(&mut link.stream, op1, FLAG_RESPONSE, seq1, &p1)
                .unwrap();
            write_frame(&mut link.stream, op0, FLAG_RESPONSE, seq0, &p0)
                .unwrap();
        });
        let mut client = WorkerLink::connect(&addr, &cfg).unwrap();
        // recv with nothing outstanding is an error, not a hang
        let err = client.recv_response().unwrap_err().to_string();
        assert!(err.contains("no request in flight"), "{err}");
        client.send_request(Op::Gather, b"first").unwrap();
        client.send_request(Op::Barrier, b"second").unwrap();
        let err = client.recv_response().unwrap_err().to_string();
        assert!(err.contains("FIFO"), "{err}");
        // a synchronous call may not jump a non-empty pipeline
        let err = client.call(Op::Barrier, &[]).unwrap_err().to_string();
        assert!(err.contains("in flight"), "{err}");
        server.join().unwrap();
    }
}
