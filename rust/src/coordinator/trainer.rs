//! The training coordinator.

use std::collections::BTreeSet;
use std::path::Path;
use std::time::Instant;

use anyhow::{bail, ensure, Result};

use crate::analysis::{
    field_scores_from_counts, plan_for_budget, static_field_scores,
};
use crate::checkpoint::journal::{self, Delta, DeltaChain, JournalWriter};
use crate::checkpoint::{self, failpoint, Checkpoint, SectionKind};
use crate::config::{Experiment, Method};
use crate::embedding::GroupedStore;
use crate::data::batcher::{
    with_prefetch, Batch, Batcher, StreamBatcher, Tail,
};
use crate::data::registry::{self, DataSource};
use crate::data::Dataset;
use crate::embedding::{build_store, EmbeddingStore, UpdateHp};
use crate::metrics::{EvalAccumulator, StreamingEval};
use crate::nn::Dcn;
use crate::optim::{Adam, LrSchedule};
use crate::quant::{lsq_delta_grad_row, BitWidth};
use crate::runtime::{
    lit_f32, lit_i32, lit_scalar, to_f32, to_scalar_f32, ModelEntry, Runtime,
};
use crate::util::rng::Pcg32;

/// Per-epoch training report.
#[derive(Clone, Debug)]
pub struct EpochReport {
    pub epoch: usize,
    pub mean_loss: f64,
    pub steps: usize,
    pub seconds: f64,
    pub val_auc: f64,
    pub val_logloss: f64,
}

/// Evaluation metrics.
#[derive(Clone, Debug)]
pub struct EvalReport {
    pub auc: f64,
    pub logloss: f64,
    pub samples: usize,
}

/// Final result of a training run.
#[derive(Clone, Debug)]
pub struct TrainResult {
    pub method: &'static str,
    pub best_auc: f64,
    pub best_logloss: f64,
    pub best_epoch: usize,
    pub epochs_run: usize,
    pub total_seconds: f64,
    pub seconds_per_epoch: f64,
    pub train_compression: f64,
    pub infer_compression: f64,
    pub history: Vec<EpochReport>,
}

/// One training step's outputs (diagnostics).
pub struct StepOutput {
    pub loss: f32,
    pub n_unique: usize,
}

/// Early-stop / best-epoch bookkeeping, carried across save/resume so a
/// resumed run stops — and reports its best epoch — exactly like an
/// uninterrupted one.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EarlyStop {
    pub best_auc: f64,
    pub best_logloss: f64,
    pub best_epoch: usize,
    /// Consecutive epochs without a val-AUC improvement.
    pub bad_epochs: usize,
}

impl Default for EarlyStop {
    fn default() -> Self {
        Self {
            best_auc: 0.0,
            best_logloss: f64::INFINITY,
            best_epoch: 0,
            bad_epochs: 0,
        }
    }
}

impl EarlyStop {
    /// Record an epoch's validation result; returns true when `patience`
    /// consecutive non-improving epochs call for stopping.
    fn observe(&mut self, epoch: usize, ev: &EvalReport, patience: usize) -> bool {
        if ev.auc > self.best_auc {
            self.best_auc = ev.auc;
            self.best_logloss = ev.logloss;
            self.best_epoch = epoch;
            self.bad_epochs = 0;
            false
        } else {
            self.bad_epochs += 1;
            patience > 0 && self.bad_epochs >= patience
        }
    }
}

/// The coordinator. See module docs for the per-batch protocol.
pub struct Trainer {
    pub exp: Experiment,
    pub entry: ModelEntry,
    runtime: Option<Runtime>,
    dcn: Dcn,
    pub store: Box<dyn EmbeddingStore>,
    pub dense: Vec<f32>,
    adam: Adam,
    schedule: LrSchedule,
    rng: Pcg32,
    mask_rng: Pcg32,
    // scratch buffers reused across steps (hot-path allocations)
    emb_buf: Vec<f32>,
    codes_buf: Vec<i32>,
    delta_buf: Vec<f32>,
    mask_buf: Vec<f32>,
    labels_buf: Vec<f32>,
    // second-pass (ALPT Δ-gradient) padded-input scratch, reused across
    // steps instead of being reallocated inside every closure call
    sp_w_pad: Vec<f32>,
    sp_d_pad: Vec<f32>,
    grad_scale_val: f32,
    /// Epochs already completed (nonzero after a resume): `train`
    /// continues at `epochs_done + 1`, so the LR schedule and per-epoch
    /// shuffle seeds pick up where the saved run stopped.
    pub epochs_done: usize,
    /// Streaming runs: records consumed from the current (unfinished)
    /// epoch's train stream — always `steps × batch_size` under
    /// [`Tail::Drop`]. Persisted in the checkpoint's progress section so
    /// `--resume` fast-forwards the deterministic stream and continues
    /// mid-epoch bit-identically. 0 at epoch boundaries.
    pub stream_records_done: u64,
    /// Best-epoch / patience bookkeeping, persisted in the checkpoint's
    /// progress section so a resumed run's early stopping continues
    /// where the saved one left off.
    pub early_stop: EarlyStop,
    /// Open delta journal for continuous checkpointing (`None` until the
    /// first [`Trainer::continuous_save`] publishes an anchor).
    journal: Option<JournalWriter>,
    /// Row ids dirtied since the last continuous save. Only maintained
    /// while a journal is open — full saves never need it.
    dirty: BTreeSet<u32>,
    /// Batch-ahead RPC pipelining for distributed stores
    /// (`--no-overlap` clears it). Local stores ignore it.
    rpc_overlap: bool,
}

impl Trainer {
    /// Build a trainer for `exp` over a feature space of `n_features`.
    pub fn new(mut exp: Experiment, n_features: usize) -> Result<Self> {
        let mut rng = Pcg32::new(exp.seed, 0x7A11);
        let runtime = if exp.use_runtime {
            Some(Runtime::load(Path::new(&exp.artifacts_dir))?)
        } else {
            None
        };
        let entry = match &runtime {
            Some(rt) => rt.entry(&exp.model)?.clone(),
            None => {
                // PJRT-free path still needs the geometry; use the static
                // configs mirrored in DcnConfig.
                builtin_entry(&exp.model)?
            }
        };
        ensure!(
            entry.layout_matches_rust(),
            "manifest layout disagrees with the Rust DCN layout"
        );
        // `auto:<bytes>` resolves into concrete per-field widths before
        // any table exists. No batch has run yet, so the ranking is the
        // data-free one (small vocab = hot rows); with --replan-budget
        // the first epoch's real counts re-derive it. The resolved plan
        // is what the checkpoint echo records, so resumed runs skip
        // straight to it.
        if let Some(budget) = exp.bits.auto_budget() {
            ensure!(
                exp.method.trains_quantized(),
                "--plan auto:{budget} picks per-field bit widths, which \
                 only quantized-training methods use; method {} has no \
                 packed table (use lpt/alpt or a concrete plan)",
                exp.method.key()
            );
            let schema = registry::schema_for(&exp)?;
            let scores = static_field_scores(&schema.vocabs);
            let resolved = plan_for_budget(
                &schema.vocabs,
                &scores,
                entry.emb_dim,
                matches!(exp.method, Method::Alpt(_)),
                budget,
                false,
            )?;
            println!(
                "auto:{budget} resolved to plan {} ({} predicted \
                 inference bytes)",
                resolved.plan.key(),
                resolved.bytes
            );
            exp.bits = resolved.plan;
        }
        let dcn = Dcn::new(entry.dcn_config());
        let dense = entry.init_params(&mut rng);
        let adam = Adam::new(dense.len(), exp.lr_dense);
        let store = build_store(&exp, n_features, entry.emb_dim, &mut rng)?;
        // §3.2 gradient scale: uniform plans use their width; mixed plans
        // use the plan's default width (g is a batch-level normalizer —
        // per-group exactness is not load-bearing)
        let bw = exp.bits.scale_width();
        let grad_scale_val =
            exp.grad_scale.value(entry.batch, entry.emb_dim, bw);
        let schedule = LrSchedule {
            milestones: exp.lr_milestones.clone(),
            gamma: exp.lr_gamma,
        };
        let umax = entry.umax;
        let (b, mmd) = (entry.batch, entry.mlp_mask_dim);
        let d = entry.emb_dim;
        Ok(Self {
            exp,
            entry,
            runtime,
            dcn,
            store,
            dense,
            adam,
            schedule,
            mask_rng: Pcg32::new(rng.next_u64(), 0xD0),
            rng,
            emb_buf: vec![0.0; umax * d],
            codes_buf: vec![0i32; umax * d],
            delta_buf: vec![1.0; umax],
            mask_buf: vec![1.0; b * mmd],
            labels_buf: vec![0.0; b],
            sp_w_pad: vec![0.0; umax * d],
            sp_d_pad: vec![1.0; umax],
            grad_scale_val,
            epochs_done: 0,
            stream_records_done: 0,
            early_stop: EarlyStop::default(),
            journal: None,
            dirty: BTreeSet::new(),
            rpc_overlap: true,
        })
    }

    /// Enable/disable batch-ahead RPC pipelining (`--no-overlap`).
    /// Takes effect immediately, including on an already-attached
    /// remote store. Checkpoints are byte-identical either way; the
    /// switch exists as a debugging escape hatch.
    pub fn set_rpc_overlap(&mut self, on: bool) {
        self.rpc_overlap = on;
        if let Some(remote) = self.store.as_remote() {
            remote.set_overlap(on);
        }
    }

    /// Current LR decay multiplier for `epoch` (1-based).
    pub fn lr_scale(&self, epoch: usize) -> f32 {
        self.schedule.scale(epoch)
    }

    fn fill_mask(&mut self) {
        let p = self.entry.dropout as f32;
        if p <= 0.0 {
            // stays all-ones
            return;
        }
        let keep = 1.0 - p;
        let inv = 1.0 / keep;
        for v in self.mask_buf.iter_mut() {
            *v = if self.mask_rng.bernoulli(keep) { inv } else { 0.0 };
        }
    }

    fn eval_mask_ones(&mut self) {
        for v in self.mask_buf.iter_mut() {
            *v = 1.0;
        }
    }

    /// One training step on a prepared batch. `epoch` is 1-based.
    pub fn step(&mut self, batch: &Batch, epoch: usize) -> Result<StepOutput> {
        let (umax, d, b, fields, mmd) = (
            self.entry.umax,
            self.entry.emb_dim,
            self.entry.batch,
            self.entry.fields,
            self.entry.mlp_mask_dim,
        );
        let n_unique = batch.unique.len();
        ensure!(n_unique <= umax, "batch uniques exceed umax");
        ensure!(batch.idx.len() == b * fields, "bad batch shape");

        // labels + dropout mask
        for (o, &l) in self.labels_buf.iter_mut().zip(&batch.labels) {
            *o = l as f32;
        }
        self.fill_mask();

        // gather the dequantized rows (needed for the update regardless of
        // which artifact runs the forward)
        self.emb_buf[n_unique * d..umax * d].fill(0.0);
        self.store
            .gather(&batch.unique, &mut self.emb_buf[..n_unique * d]);

        let quantized = self.store.quantized_view(
            &batch.unique,
            &mut self.codes_buf[..n_unique * d],
            &mut self.delta_buf[..n_unique],
        );
        if quantized {
            self.codes_buf[n_unique * d..umax * d].fill(0);
            self.delta_buf[n_unique..umax].fill(1.0);
        }

        let lr_scale = self.schedule.scale(epoch);
        let hp = UpdateHp {
            lr_emb: self.exp.lr_emb,
            wd_emb: self.exp.wd_emb,
            lr_delta: self.exp.lr_delta,
            wd_delta: self.exp.wd_delta,
            grad_scale: self.grad_scale_val,
            lr_scale,
        };

        let (loss, d_emb, d_params) = if let Some(rt) = self.runtime.as_mut()
        {
            let (udim, ddim) = (umax as i64, d as i64);
            let idx_lit =
                lit_i32(&batch.idx, &[b as i64, fields as i64])?;
            let labels_lit = lit_f32(&self.labels_buf, &[b as i64])?;
            let params_lit = lit_f32(&self.dense, &[self.dense.len() as i64])?;
            let mask_lit =
                lit_f32(&self.mask_buf, &[b as i64, mmd as i64])?;
            let outs = if quantized {
                rt.exec(
                    &self.exp.model,
                    "train_lpt",
                    &[
                        lit_i32(&self.codes_buf, &[udim, ddim])?,
                        lit_f32(&self.delta_buf, &[udim])?,
                        idx_lit,
                        labels_lit,
                        params_lit,
                        mask_lit,
                    ],
                )?
            } else {
                rt.exec(
                    &self.exp.model,
                    "train_fp",
                    &[
                        lit_f32(&self.emb_buf, &[udim, ddim])?,
                        idx_lit,
                        labels_lit,
                        params_lit,
                        mask_lit,
                    ],
                )?
            };
            ensure!(outs.len() == 4, "train artifact returned {} outputs",
                    outs.len());
            let loss = to_scalar_f32(&outs[0])?;
            let d_emb = to_f32(&outs[2])?;
            let d_params = to_f32(&outs[3])?;
            (loss, d_emb, d_params)
        } else {
            let out = self.dcn.train_step(
                &self.emb_buf,
                &batch.idx,
                &batch.labels,
                &self.dense,
                &self.mask_buf,
                umax,
            );
            (out.loss, out.d_emb, out.d_params)
        };

        // dense update first: Algorithm 1 step 2 evaluates at w_o^{t+1}
        self.adam.step(&mut self.dense, &d_params, lr_scale);

        // embedding update (+ ALPT's second pass through train_fq)
        let model = self.exp.model.clone();
        let runtime = &mut self.runtime;
        let dcn = &self.dcn;
        let dense = &self.dense;
        let mask_buf = &self.mask_buf;
        let labels_buf = &self.labels_buf;
        let labels_u8 = &batch.labels;
        let idx = &batch.idx;
        // padded second-pass inputs live in trainer scratch, not in fresh
        // per-call allocations
        let sp_w_pad = &mut self.sp_w_pad;
        let sp_d_pad = &mut self.sp_d_pad;
        let mut second_pass = |w_new: &[f32],
                               delta: &[f32],
                               bws: &[BitWidth]|
         -> Result<Vec<f32>> {
            debug_assert_eq!(w_new.len(), delta.len() * d);
            debug_assert_eq!(bws.len(), delta.len());
            let n_u = delta.len();
            // the delta_grad artifact takes one scalar (qn, qp) pair, so
            // it can only serve batches whose rows share one width;
            // mixed-precision groups fall through to the Rust path below
            // (identical math, per-row bounds)
            let uniform_bw = bws
                .first()
                .copied()
                .filter(|&b| bws.iter().all(|&x| x == b));
            if let (Some(rt), Some(bw)) = (runtime.as_mut(), uniform_bw) {
                sp_w_pad[..n_u * d].copy_from_slice(w_new);
                sp_w_pad[n_u * d..].fill(0.0);
                sp_d_pad[..n_u].copy_from_slice(delta);
                sp_d_pad[n_u..].fill(1.0);
                // `delta_grad` is the lean variant of train_fq: XLA DCEs
                // the weight/dense backward and only d_delta crosses the
                // host boundary (see EXPERIMENTS.md §Perf).
                let outs = rt.exec(
                    &model,
                    "delta_grad",
                    &[
                        lit_f32(sp_w_pad, &[umax as i64, d as i64])?,
                        lit_f32(sp_d_pad, &[umax as i64])?,
                        lit_i32(idx, &[b as i64, fields as i64])?,
                        lit_f32(labels_buf, &[b as i64])?,
                        lit_f32(dense, &[dense.len() as i64])?,
                        lit_f32(mask_buf, &[b as i64, mmd as i64])?,
                        lit_scalar(bw.qn() as f32),
                        lit_scalar(bw.qp() as f32),
                    ],
                )?;
                ensure!(outs.len() == 1, "delta_grad returned {} outputs",
                        outs.len());
                let mut d_delta = to_f32(&outs[0])?;
                d_delta.truncate(n_u);
                Ok(d_delta)
            } else {
                // Rust fallback: fake-quant forward + Eq. 7 reduction —
                // the same math the train_fq artifact performs, with each
                // row clamped to its own group's (qn, qp).
                for i in 0..n_u {
                    let dl = delta[i];
                    let (qn, qp) =
                        (bws[i].qn() as f32, bws[i].qp() as f32);
                    for j in 0..d {
                        let x =
                            (w_new[i * d + j] / dl).clamp(qn, qp);
                        sp_w_pad[i * d + j] = (x + 0.5).floor() * dl;
                    }
                }
                sp_w_pad[n_u * d..].fill(0.0);
                let out = dcn.train_step(sp_w_pad, idx, labels_u8, dense,
                                         mask_buf, umax);
                Ok((0..n_u)
                    .map(|i| {
                        lsq_delta_grad_row(
                            &w_new[i * d..(i + 1) * d],
                            delta[i],
                            bws[i],
                            &out.d_emb[i * d..(i + 1) * d],
                        )
                    })
                    .collect())
            }
        };

        self.store.update(
            &batch.unique,
            &self.emb_buf[..n_unique * d],
            &d_emb[..n_unique * d],
            &hp,
            &mut self.rng,
            &mut second_pass,
        )?;
        self.store.end_step();

        // rows this step touched become part of the next delta; only
        // tracked while a journal is open (full saves never need it)
        if self.journal.is_some() {
            self.dirty.extend(batch.unique.iter().copied());
        }

        Ok(StepOutput { loss, n_unique })
    }

    /// Inference logits for one batch (runtime artifact or the shared
    /// [`crate::serve::score_batch`] body the online inference subsystem
    /// uses). Callers must have set the eval mask; shared by the
    /// in-memory and streaming evaluation loops.
    fn batch_logits(&mut self, batch: &Batch) -> Result<Vec<f32>> {
        let (umax, d, b, fields) = (
            self.entry.umax,
            self.entry.emb_dim,
            self.entry.batch,
            self.entry.fields,
        );
        let n_unique = batch.unique.len();
        ensure!(n_unique <= umax, "batch uniques exceed umax");
        if self.runtime.is_none() {
            // the PJRT-free path is exactly the serving path: the one
            // shared gather → DCN-forward body, evaluated over the
            // trainer's scratch buffer
            return Ok(crate::serve::score_batch(
                self.store.as_ref(),
                &self.dcn,
                &self.dense,
                umax,
                batch,
                &mut self.emb_buf,
            ));
        }
        self.emb_buf[n_unique * d..umax * d].fill(0.0);
        self.store
            .gather(&batch.unique, &mut self.emb_buf[..n_unique * d]);
        let quantized = self.store.quantized_view(
            &batch.unique,
            &mut self.codes_buf[..n_unique * d],
            &mut self.delta_buf[..n_unique],
        );
        if quantized {
            self.codes_buf[n_unique * d..umax * d].fill(0);
            self.delta_buf[n_unique..umax].fill(1.0);
        }
        let rt = self.runtime.as_mut().expect("checked above");
        let idx_lit = lit_i32(&batch.idx, &[b as i64, fields as i64])?;
        let params_lit = lit_f32(&self.dense, &[self.dense.len() as i64])?;
        let outs = if quantized {
            rt.exec(
                &self.exp.model,
                "eval_lpt",
                &[
                    lit_i32(&self.codes_buf, &[umax as i64, d as i64])?,
                    lit_f32(&self.delta_buf, &[umax as i64])?,
                    idx_lit,
                    params_lit,
                ],
            )?
        } else {
            rt.exec(
                &self.exp.model,
                "eval_fp",
                &[
                    lit_f32(&self.emb_buf, &[umax as i64, d as i64])?,
                    idx_lit,
                    params_lit,
                ],
            )?
        };
        to_f32(&outs[0])
    }

    /// Evaluate on a dataset (deterministic order, padded final batch).
    pub fn evaluate(&mut self, ds: &Dataset) -> Result<EvalReport> {
        self.eval_mask_ones();
        let b = self.entry.batch;
        let mut acc = EvalAccumulator::new();
        for batch in Batcher::new(ds, b, None, false) {
            let logits = self.batch_logits(&batch)?;
            acc.push(&logits, &batch.labels, batch.valid);
        }
        Ok(EvalReport {
            auc: acc.auc(),
            logloss: acc.logloss(),
            samples: acc.len(),
        })
    }

    /// Can this trainer consume records from `source`? Delegates to the
    /// one shared rule in [`registry::ensure_compat`].
    fn ensure_source_compat(&self, source: &dyn DataSource) -> Result<()> {
        registry::ensure_compat(
            source,
            &self.entry.name,
            self.entry.fields,
            self.store.n_features(),
        )
    }

    /// Evaluate on a source's held-out split (streaming: fixed-memory
    /// accumulator, deterministic order, padded final batch).
    pub fn evaluate_source(
        &mut self,
        source: &dyn DataSource,
    ) -> Result<EvalReport> {
        self.ensure_source_compat(source)?;
        self.eval_mask_ones();
        let (b, f) = (self.entry.batch, self.entry.fields);
        let stream = registry::val_stream(source, &self.exp)?;
        let mut acc = StreamingEval::new();
        for item in StreamBatcher::new(stream, f, b, Tail::Pad) {
            let batch = item?;
            let logits = self.batch_logits(&batch)?;
            acc.push(&logits, &batch.labels, batch.valid);
        }
        Ok(EvalReport {
            auc: acc.auc(),
            logloss: acc.logloss(),
            samples: acc.len(),
        })
    }

    /// Full training run: epochs, per-epoch validation, early stop on val
    /// AUC with the configured patience, final metrics from the best epoch.
    pub fn train(
        &mut self,
        train: &Dataset,
        val: &Dataset,
        verbose: bool,
    ) -> Result<TrainResult> {
        let t0 = Instant::now();
        let mut history = Vec::new();

        // a resumed trainer picks up the epoch numbering where it left
        // off — LR decay, per-epoch shuffle seeds and the early-stop
        // bookkeeping continue, they are not replayed from epoch 1
        let start_epoch = self.epochs_done + 1;
        for epoch in start_epoch..=self.exp.epochs {
            let e0 = Instant::now();
            let seed = self.exp.seed ^ (epoch as u64).wrapping_mul(0x9E37);
            let batches: Vec<Batch> =
                Batcher::new(train, self.entry.batch, Some(seed), true)
                    .collect();
            let mut loss_sum = 0.0f64;
            let mut steps = 0usize;
            for (i, batch) in batches.iter().enumerate() {
                let out = self.step(batch, epoch)?;
                loss_sum += out.loss as f64;
                steps += 1;
                // feed the next batch's ids into the RPC pipeline: the
                // GATHER goes out right behind this batch's UPDATE
                // frames (a no-op for local stores / --no-overlap)
                if let Some(next) = batches.get(i + 1) {
                    self.store.prefetch_ids(&next.unique);
                }
            }
            // epoch barrier: every worker acks (liveness + all updates
            // applied) before validation reads the table
            if let Some(remote) = self.store.as_remote() {
                remote.barrier()?;
            }
            let ev = self.evaluate(val)?;
            let report = EpochReport {
                epoch,
                mean_loss: loss_sum / steps.max(1) as f64,
                steps,
                seconds: e0.elapsed().as_secs_f64(),
                val_auc: ev.auc,
                val_logloss: ev.logloss,
            };
            if verbose {
                println!(
                    "  [{}] epoch {epoch:>2}: loss {:.5}  val auc {:.4}  \
                     val logloss {:.5}  ({:.1}s, {} steps)",
                    self.store.method_name(),
                    report.mean_loss,
                    report.val_auc,
                    report.val_logloss,
                    report.seconds,
                    report.steps
                );
            }
            history.push(report);
            self.epochs_done = epoch;
            if self.early_stop.observe(epoch, &ev, self.exp.patience) {
                break;
            }
            if epoch < self.exp.epochs {
                self.replan_at_boundary(verbose)?;
            }
        }

        Ok(self.train_result(t0, history))
    }

    /// End-of-epoch online re-planning (`--replan-budget`): re-derive a
    /// budgeted plan from the epoch's per-row access counts and, when it
    /// differs from the current one, migrate every row into a fresh
    /// [`GroupedStore`] via the deterministic requantize-on-migrate path.
    /// The counters reset afterwards either way, so each boundary ranks
    /// fields by the *latest* epoch's traffic — and a checkpoint written
    /// after the boundary resumes bit-identically (counts are in-memory
    /// only and start the next epoch at zero in both runs).
    ///
    /// Called between epochs only (never after the last), and a no-op
    /// unless re-planning is on.
    fn replan_at_boundary(&mut self, verbose: bool) -> Result<()> {
        let budget = self.exp.replan_budget as u64;
        if budget == 0 {
            return Ok(());
        }
        let Some(gs) = self.store.as_grouped() else {
            // build_store routes every re-planning run through the
            // grouped store; a different store means a resumed
            // pre-replan checkpoint — leave it alone
            return Ok(());
        };
        if gs.has_structural_groups() {
            eprintln!(
                "warning: skipping end-of-epoch re-planning: the current \
                 plan has hashed/pruned groups, whose shared parameters \
                 cannot be migrated row-by-row"
            );
            self.store.reset_access_counts();
            return Ok(());
        }
        let schema = registry::schema_for(&self.exp)?;
        let counts = self
            .store
            .access_counts()
            .expect("grouped stores track access counts");
        ensure!(
            counts.len() >= schema.n_features(),
            "access counters cover {} rows, schema needs {}",
            counts.len(),
            schema.n_features()
        );
        let scores = field_scores_from_counts(counts, &schema);
        let resolved = plan_for_budget(
            &schema.vocabs,
            &scores,
            self.entry.emb_dim,
            matches!(self.exp.method, Method::Alpt(_)),
            budget,
            false,
        )?;
        if resolved.plan != self.exp.bits {
            let kinds = registry::field_kinds(&self.exp)?;
            let mut new_exp = self.exp.clone();
            new_exp.bits = resolved.plan.clone();
            let old = self
                .store
                .as_grouped()
                .expect("checked above");
            let migrated = GroupedStore::migrate_from(
                old, &new_exp, &schema, &kinds, &mut self.rng,
            )?;
            self.store = Box::new(migrated);
            self.exp.bits = resolved.plan;
            // §3.2 gradient scale follows the plan's default width, the
            // same value a run resumed under the new plan computes
            self.grad_scale_val = self.exp.grad_scale.value(
                self.entry.batch,
                self.entry.emb_dim,
                self.exp.bits.scale_width(),
            );
            // rows moved between groups: any open delta journal describes
            // the old layout, so the next continuous save re-anchors
            self.journal = None;
            self.dirty.clear();
            if verbose {
                println!(
                    "  [replan] plan -> {} ({} predicted bytes / budget \
                     {budget})",
                    self.exp.bits.key(),
                    resolved.bytes
                );
            }
        }
        self.store.reset_access_counts();
        Ok(())
    }

    /// Assemble the [`TrainResult`] both training loops return.
    fn train_result(
        &self,
        t0: Instant,
        history: Vec<EpochReport>,
    ) -> TrainResult {
        let total = t0.elapsed().as_secs_f64();
        let fp =
            crate::embedding::fp_bytes(self.store.n_features(),
                                       self.entry.emb_dim) as f64;
        let epochs_run = history.len();
        TrainResult {
            method: self.store.method_name(),
            best_auc: self.early_stop.best_auc,
            best_logloss: self.early_stop.best_logloss,
            best_epoch: self.early_stop.best_epoch,
            epochs_run,
            total_seconds: total,
            seconds_per_epoch: total / epochs_run.max(1) as f64,
            train_compression: fp / self.store.train_bytes() as f64,
            infer_compression: fp / self.store.infer_bytes() as f64,
            history,
        }
    }

    /// Full training run over a streaming [`DataSource`] — the streaming
    /// counterpart of [`Trainer::train`]: per epoch, holdout split →
    /// seeded window shuffle → fixed-size batches (assembled on a
    /// background thread when `exp.prefetch_batches > 0`, bit-identically
    /// to the serial path), then held-out evaluation and early stop on
    /// val AUC.
    ///
    /// With `save_to` set and `exp.save_every > 0`, state is persisted
    /// every `save_every` steps through [`Trainer::continuous_save`]
    /// (full anchor first, CRC-chained deltas after, periodic
    /// compaction); a trainer resumed from it continues bit-identically,
    /// *including mid-epoch* — the persisted stream position
    /// fast-forwards the deterministic record stream.
    pub fn train_stream(
        &mut self,
        source: &dyn DataSource,
        verbose: bool,
        save_to: Option<&Path>,
    ) -> Result<TrainResult> {
        self.ensure_source_compat(source)?;
        let t0 = Instant::now();
        let (b, f) = (self.entry.batch, self.entry.fields);
        let mut history = Vec::new();

        let start_epoch = self.epochs_done + 1;
        // a mid-epoch resume fast-forwards the first epoch's stream past
        // the records the saved run already consumed
        let mut skip = self.stream_records_done;
        for epoch in start_epoch..=self.exp.epochs {
            let e0 = Instant::now();
            let mut stream =
                registry::train_epoch_stream(source, &self.exp, epoch)?;
            if skip > 0 {
                registry::skip_records(stream.as_mut(), f, skip)?;
            }
            self.stream_records_done = skip;
            skip = 0;
            let mut loss_sum = 0.0f64;
            let mut steps = 0usize;
            let save_every = self.exp.save_every;
            let depth = self.exp.prefetch_batches;
            let mut on_batch = |trainer: &mut Trainer,
                                batch: Batch|
             -> Result<bool> {
                let out = trainer.step(&batch, epoch)?;
                loss_sum += out.loss as f64;
                steps += 1;
                trainer.stream_records_done += b as u64;
                if save_every > 0 && steps % save_every == 0 {
                    if let Some(path) = save_to {
                        trainer.continuous_save(path)?;
                    }
                }
                Ok(true)
            };
            // one-batch lookahead so the distributed store can issue
            // batch k+1's GATHER right after batch k's UPDATE frames:
            // hold each batch until its successor arrives, step the
            // held one, then hand the successor's ids to the pipeline
            let mut held: Option<Batch> = None;
            if depth > 0 {
                with_prefetch(stream, f, b, Tail::Drop, depth, |batch| {
                    if let Some(prev) = held.take() {
                        on_batch(self, prev)?;
                        self.store.prefetch_ids(&batch.unique);
                    }
                    held = Some(batch);
                    Ok(true)
                })?;
            } else {
                for item in StreamBatcher::new(stream, f, b, Tail::Drop) {
                    let batch = item?;
                    if let Some(prev) = held.take() {
                        on_batch(self, prev)?;
                        self.store.prefetch_ids(&batch.unique);
                    }
                    held = Some(batch);
                }
            }
            // the final batch has no successor, so no prefetch is left
            // outstanding when the epoch barrier / evaluation runs
            if let Some(last) = held.take() {
                on_batch(self, last)?;
            }
            // a fresh epoch that yields not even one full batch means the
            // source is effectively empty for training (file too small —
            // or every line malformed); completing "successfully" with
            // zero steps would just report a chance-level AUC. A resumed
            // tail (skip consumed the epoch) is the one legitimate case.
            ensure!(
                steps > 0 || self.stream_records_done > 0,
                "epoch {epoch}: the training split of {} produced no \
                 full batch of {b} records — is the file empty, too \
                 small, or entirely malformed?",
                source.name()
            );
            self.stream_records_done = 0;
            self.epochs_done = epoch;

            // epoch barrier: every worker acks (liveness + all updates
            // applied) before validation reads the table
            if let Some(remote) = self.store.as_remote() {
                remote.barrier()?;
            }
            let ev = self.evaluate_source(source)?;
            let report = EpochReport {
                epoch,
                mean_loss: loss_sum / steps.max(1) as f64,
                steps,
                seconds: e0.elapsed().as_secs_f64(),
                val_auc: ev.auc,
                val_logloss: ev.logloss,
            };
            if verbose {
                println!(
                    "  [{}] epoch {epoch:>2}: loss {:.5}  val auc {:.4}  \
                     val logloss {:.5}  ({:.1}s, {} steps)",
                    self.store.method_name(),
                    report.mean_loss,
                    report.val_auc,
                    report.val_logloss,
                    report.seconds,
                    report.steps
                );
            }
            history.push(report);
            if self.early_stop.observe(epoch, &ev, self.exp.patience) {
                break;
            }
            if epoch < self.exp.epochs {
                self.replan_at_boundary(verbose)?;
            }
        }

        Ok(self.train_result(t0, history))
    }

    /// Is this trainer using the PJRT runtime (vs the Rust nn fallback)?
    pub fn uses_runtime(&self) -> bool {
        self.runtime.is_some()
    }

    // ------------------------------------------------- distributed training

    /// Shard the embedding table across `workers` remote processes:
    /// bind `listen`, wait for `workers` registrations, stream the rows
    /// out, and swap the local store for the RPC-backed
    /// [`RemoteStore`]. Training afterwards is bit-identical to the
    /// local run — see the determinism notes in `embedding::remote`.
    ///
    /// Works on fresh and resumed trainers alike (the worker layout is
    /// CLI-level state, never part of the experiment or checkpoint).
    pub fn attach_workers(
        &mut self,
        listen: &str,
        workers: usize,
        cfg: crate::coordinator::net::RpcConfig,
    ) -> Result<()> {
        let hub = crate::coordinator::net::WorkerHub::bind(listen, cfg)?;
        println!(
            "waiting for {workers} worker(s) on {} ...",
            hub.local_addr()?
        );
        self.attach_workers_hub(hub, workers)
    }

    /// [`Trainer::attach_workers`] over a pre-bound hub (tests bind
    /// port 0 and read the assigned address back).
    pub fn attach_workers_hub(
        &mut self,
        hub: crate::coordinator::net::WorkerHub,
        workers: usize,
    ) -> Result<()> {
        let remote = crate::embedding::RemoteStore::attach(
            self.store.as_ref(),
            &self.exp,
            hub,
            workers,
        )?;
        remote.set_overlap(self.rpc_overlap);
        println!(
            "embedding table sharded across {workers} worker(s): {} rows, \
             {} per shard (max)",
            remote.n_features(),
            crate::coordinator::sharding::RowPartition::new(
                remote.n_features(),
                workers
            )
            .shard_rows(0),
        );
        self.store = Box::new(remote);
        // any open journal addresses the local table; continuous saves
        // re-anchor (remote stores opt out of journaling anyway)
        self.journal = None;
        self.dirty.clear();
        Ok(())
    }

    // ------------------------------------------------------ checkpointing

    /// Serialize the full training state to one checkpoint file: the
    /// store's packed rows + per-row scalars (via the `checkpoint`
    /// subsystem), the dense parameters, the Adam moments, and both
    /// generator states. The file is staged and atomically published
    /// (see `checkpoint::writer`); the returned anchor id is what a
    /// delta journal chains off. A trainer resumed from the file
    /// continues *bit-identically* to an uninterrupted run — see the
    /// `StreamKey` determinism contract in `util::rng`.
    pub fn save_checkpoint(&mut self, path: &Path) -> Result<u32> {
        // local stores no-op; a remote store quiesces its workers and
        // mirrors the Δ table so the sections below see coherent state
        self.store.prepare_save()?;
        let mut w =
            checkpoint::writer_for_store(path, self.store.as_ref())?;
        checkpoint::write_store_sections(&mut w, self.store.as_ref(),
                                         &self.exp)?;

        let mut buf = Vec::with_capacity(self.dense.len() * 4);
        checkpoint::format::put_f32s(&mut buf, &self.dense);
        w.section(SectionKind::Dense, 0, &buf)?;

        let (m, v, t) = self.adam.state();
        buf.clear();
        checkpoint::format::put_u64(&mut buf, t);
        checkpoint::format::put_f32s(&mut buf, m);
        checkpoint::format::put_f32s(&mut buf, v);
        w.section(SectionKind::Optimizer, 0, &buf)?;

        buf.clear();
        let (rs, ri) = self.rng.state();
        let (ms, mi) = self.mask_rng.state();
        for x in [rs, ri, ms, mi] {
            checkpoint::format::put_u64(&mut buf, x);
        }
        w.section(SectionKind::Rng, 0, &buf)?;

        buf.clear();
        checkpoint::format::put_u64(&mut buf, self.epochs_done as u64);
        checkpoint::format::put_u64(&mut buf, self.stream_records_done);
        checkpoint::format::put_u64(&mut buf, self.early_stop.best_epoch as u64);
        checkpoint::format::put_u64(&mut buf, self.early_stop.bad_epochs as u64);
        checkpoint::format::put_u64(&mut buf, self.early_stop.best_auc.to_bits());
        checkpoint::format::put_u64(&mut buf,
                                    self.early_stop.best_logloss.to_bits());
        w.section(SectionKind::Progress, 0, &buf)?;
        w.finish()
    }

    /// Continuous checkpointing: called every `--save-every` steps by
    /// the streaming loop. The first call (per run — fresh or resumed)
    /// publishes a full anchor and opens a fresh journal; later calls
    /// append a CRC-chained delta of only the rows dirtied since the
    /// previous call; every `compact_every` deltas the chain is folded
    /// into a new anchor (a full save — the trainer *is* the folded
    /// state) and the journal starts over. Failpoint sites:
    /// `compact.anchor` / `compact.reset` around compaction, plus every
    /// writer and appender site inside.
    pub fn continuous_save(&mut self, path: &Path) -> Result<()> {
        // aux-only stores (hashing) and grouped stores with structural
        // groups have no per-row delta payload to journal, and remote
        // stores opt out (supports_delta_journal); every continuous
        // save is a full anchor for them
        let journaled = self.store.supports_delta_journal()
            && match self.store.as_grouped() {
                Some(gs) => !gs.has_structural_groups(),
                None => self.store.ckpt_row_bytes().is_some(),
            };
        if !journaled {
            self.save_checkpoint(path)?;
            self.dirty.clear();
            return Ok(());
        }
        let compact_every = match self.exp.compact_every {
            0 => 64,
            n => n as u64,
        };
        let reanchor = match &self.journal {
            None => true,
            Some(j) => j.len() >= compact_every,
        };
        if reanchor {
            let compacting = self.journal.is_some();
            if compacting {
                // close the superseded chain before re-anchoring; its
                // file stays on disk (and readable) until the reset
                self.journal = None;
                failpoint::hit("compact.anchor");
            }
            let anchor = self.save_checkpoint(path)?;
            if compacting {
                failpoint::hit("compact.reset");
            }
            self.journal = Some(JournalWriter::create(
                path,
                anchor,
                self.store.step_counter(),
            )?);
        } else {
            let delta = self.capture_delta();
            let (rows, aux) =
                journal::capture_rows(self.store.as_ref(), &delta.ids)?;
            let delta = Delta { rows, aux, ..delta };
            self.journal
                .as_mut()
                .expect("journal open in the append branch")
                .append(&delta)?;
        }
        self.dirty.clear();
        Ok(())
    }

    /// Snapshot the per-step trainer state into a [`Delta`] (rows and
    /// aux are filled in by the caller from the dirty set).
    fn capture_delta(&self) -> Delta {
        let (m, v, t) = self.adam.state();
        let mut opt = Vec::with_capacity(8 + (m.len() + v.len()) * 4);
        checkpoint::format::put_u64(&mut opt, t);
        checkpoint::format::put_f32s(&mut opt, m);
        checkpoint::format::put_f32s(&mut opt, v);
        let (rs, ri) = self.rng.state();
        let (ms, mi) = self.mask_rng.state();
        Delta {
            store_step: self.store.step_counter(),
            ids: self.dirty.iter().copied().collect(),
            rows: Vec::new(),
            aux: Vec::new(),
            dense: self.dense.clone(),
            opt,
            rng: [rs, ri, ms, mi],
            progress: [
                self.epochs_done as u64,
                self.stream_records_done,
                self.early_stop.best_epoch as u64,
                self.early_stop.bad_epochs as u64,
                self.early_stop.best_auc.to_bits(),
                self.early_stop.best_logloss.to_bits(),
            ],
        }
    }

    /// Rebuild a trainer from a checkpoint written by
    /// [`Trainer::save_checkpoint`]. The experiment configuration comes
    /// from the file's metadata echo; every piece of mutable training
    /// state is then overwritten with the persisted values. A delta
    /// journal chained off this anchor is validated and folded in, so
    /// resuming from anchor + chain lands on exactly the state of the
    /// last published delta.
    pub fn resume(path: &Path) -> Result<Trainer> {
        let ckpt = Checkpoint::read(path)?;
        let exp =
            checkpoint::experiment_from_json(ckpt.meta.get("experiment")?)?;
        let n_features = ckpt.meta_usize("n")?;
        let mut trainer = Trainer::new(exp, n_features)?;
        trainer.restore_from(&ckpt)?;
        let anchor_step = ckpt.meta_usize("step")? as u64;
        if let Some(chain) =
            journal::read_chain(path, ckpt.anchor_id(), anchor_step)?
        {
            if chain.salvaged_bytes > 0 {
                eprintln!(
                    "[resume] journal tail torn by a crash: ignoring \
                     the last {} bytes",
                    chain.salvaged_bytes
                );
            }
            trainer.apply_chain(&chain)?;
        }
        Ok(trainer)
    }

    /// Fold a validated delta chain onto the freshly-restored anchor
    /// state: every delta's dirty rows apply in sequence; the dense /
    /// optimizer / generator / progress state come from the last link
    /// (each delta carries them whole).
    fn apply_chain(&mut self, chain: &DeltaChain) -> Result<()> {
        for d in &chain.deltas {
            journal::apply_rows(self.store.as_mut(), d)?;
        }
        let Some(last) = chain.deltas.last() else {
            return Ok(());
        };
        ensure!(
            last.dense.len() == self.dense.len(),
            "delta carries {} dense params, model {} expects {}",
            last.dense.len(),
            self.entry.name,
            self.dense.len()
        );
        ensure!(
            last.opt.len() == 8 + self.dense.len() * 8,
            "delta optimizer blob is {} bytes, expected {}",
            last.opt.len(),
            8 + self.dense.len() * 8
        );
        let mut pos = 0usize;
        let t = checkpoint::format::take_u64(&last.opt, &mut pos)?;
        let moments = checkpoint::format::parse_f32s(&last.opt[pos..])?;
        let (m, v) = moments.split_at(self.dense.len());
        self.adam.load_state(m, v, t)?;
        self.dense = last.dense.clone();
        self.rng = Pcg32::from_state(last.rng[0], last.rng[1]);
        self.mask_rng = Pcg32::from_state(last.rng[2], last.rng[3]);
        self.epochs_done = last.progress[0] as usize;
        self.stream_records_done = last.progress[1];
        self.early_stop = EarlyStop {
            best_epoch: last.progress[2] as usize,
            bad_epochs: last.progress[3] as usize,
            best_auc: f64::from_bits(last.progress[4]),
            best_logloss: f64::from_bits(last.progress[5]),
        };
        Ok(())
    }

    /// Overwrite this trainer's mutable state from a validated
    /// checkpoint (store rows/scalars/step, dense params, Adam moments,
    /// generator states). The checkpoint must describe this trainer's
    /// configuration: method, store geometry, and every trainer-state
    /// section are parsed and validated *before* any trainer state is
    /// mutated, and the rows then load straight into the existing store —
    /// no second table is ever built. If an error does escape after that
    /// point (e.g. a row payload failing the packed-padding invariant
    /// mid-load), discard the trainer rather than keep using it.
    pub fn restore_from(&mut self, ckpt: &Checkpoint) -> Result<()> {
        ensure!(
            ckpt.meta_str("method")? == self.exp.method.key(),
            "checkpoint method {:?} does not match this trainer's {:?}",
            ckpt.meta_str("method")?,
            self.exp.method.key()
        );

        // parse + validate every trainer-state section up front
        let dense = checkpoint::dense_params(ckpt)?;
        ensure!(
            dense.len() == self.dense.len(),
            "checkpoint holds {} dense params, model {} expects {}",
            dense.len(),
            self.entry.name,
            self.dense.len()
        );

        let opt = ckpt.section(SectionKind::Optimizer, 0)?.payload;
        ensure!(
            opt.len() == 8 + dense.len() * 8,
            "optimizer section is {} bytes, expected {}",
            opt.len(),
            8 + dense.len() * 8
        );
        let mut pos = 0usize;
        let t = checkpoint::format::take_u64(opt, &mut pos)?;
        let moments = checkpoint::format::parse_f32s(&opt[pos..])?;

        let rng_payload = ckpt.section(SectionKind::Rng, 0)?.payload;
        ensure!(
            rng_payload.len() == 32,
            "rng section is {} bytes, expected 32",
            rng_payload.len()
        );
        let mut pos = 0usize;
        let rs = checkpoint::format::take_u64(rng_payload, &mut pos)?;
        let ri = checkpoint::format::take_u64(rng_payload, &mut pos)?;
        let ms = checkpoint::format::take_u64(rng_payload, &mut pos)?;
        let mi = checkpoint::format::take_u64(rng_payload, &mut pos)?;

        let progress = ckpt.section(SectionKind::Progress, 0)?.payload;
        ensure!(
            matches!(progress.len(), 8 | 16 | 48),
            "progress section is {} bytes, expected 8, 16 or 48",
            progress.len()
        );
        let mut pos = 0usize;
        let epochs_done =
            checkpoint::format::take_u64(progress, &mut pos)? as usize;
        // pre-streaming checkpoints carry no stream position, and
        // pre-early-stop ones no best-epoch bookkeeping
        let stream_records_done = if progress.len() >= 16 {
            checkpoint::format::take_u64(progress, &mut pos)?
        } else {
            0
        };
        let early_stop = if progress.len() >= 48 {
            let best_epoch =
                checkpoint::format::take_u64(progress, &mut pos)? as usize;
            let bad_epochs =
                checkpoint::format::take_u64(progress, &mut pos)? as usize;
            let best_auc = f64::from_bits(
                checkpoint::format::take_u64(progress, &mut pos)?,
            );
            let best_logloss = f64::from_bits(
                checkpoint::format::take_u64(progress, &mut pos)?,
            );
            EarlyStop { best_auc, best_logloss, best_epoch, bad_epochs }
        } else {
            EarlyStop::default()
        };

        // all sections validated — now mutate
        checkpoint::load_store_into(self.store.as_mut(), ckpt)?;
        let (m, v) = moments.split_at(dense.len());
        self.adam.load_state(m, v, t)?;
        self.dense = dense;
        self.rng = Pcg32::from_state(rs, ri);
        self.mask_rng = Pcg32::from_state(ms, mi);
        self.epochs_done = epochs_done;
        self.stream_records_done = stream_records_done;
        self.early_stop = early_stop;
        Ok(())
    }
}

/// Static geometries for the PJRT-free path (must mirror
/// `python/compile/configs.py`). Public so runtime-free consumers (the
/// serve example / `alpt serve`) can rebuild a model's geometry from a
/// checkpoint's `model` echo alone.
pub fn builtin_entry(model: &str) -> Result<ModelEntry> {
    use crate::nn::DcnConfig;
    let (cfg, dropout) = match model {
        "tiny" => (DcnConfig::tiny(), 0.0),
        "avazu" => (
            DcnConfig {
                fields: 24,
                emb_dim: 16,
                batch: 256,
                cross_depth: 3,
                mlp: vec![256, 128, 64],
            },
            0.0,
        ),
        "criteo" => (
            DcnConfig {
                fields: 39,
                emb_dim: 16,
                batch: 256,
                cross_depth: 5,
                mlp: vec![200, 200, 200, 200, 200],
            },
            0.2,
        ),
        "avazu_d32" => (
            DcnConfig {
                fields: 24,
                emb_dim: 32,
                batch: 256,
                cross_depth: 3,
                mlp: vec![256, 128, 64],
            },
            0.0,
        ),
        "criteo_d32" => (
            DcnConfig {
                fields: 39,
                emb_dim: 32,
                batch: 256,
                cross_depth: 5,
                mlp: vec![200, 200, 200, 200, 200],
            },
            0.2,
        ),
        other => bail!("unknown model config {other:?}"),
    };
    Ok(entry_from_dcn(model, &cfg, dropout))
}

/// Build a `ModelEntry` from a Rust-side DcnConfig (no manifest needed).
pub fn entry_from_dcn(
    name: &str,
    cfg: &crate::nn::DcnConfig,
    dropout: f64,
) -> ModelEntry {
    use crate::nn::dcn::Init;
    let mut params = cfg
        .param_layout()
        .into_iter()
        .map(|(pname, r, c, init)| crate::runtime::ParamSpec {
            name: pname,
            shape: if c == 1 { vec![r] } else { vec![r, c] },
            init: match init {
                Init::Xavier => "xavier".into(),
                Init::Normal => "normal".into(),
                Init::Zero => "zero".into(),
            },
        })
        .collect::<Vec<_>>();
    // vectors are 1-D in the python layout except final_w: [k+m, 1]
    for p in params.iter_mut() {
        if p.name == "final_w" && p.shape.len() == 1 {
            p.shape = vec![p.shape[0], 1];
        }
    }
    ModelEntry {
        name: name.to_string(),
        fields: cfg.fields,
        emb_dim: cfg.emb_dim,
        batch: cfg.batch,
        umax: cfg.batch * cfg.fields,
        cross_depth: cfg.cross_depth,
        mlp: cfg.mlp.clone(),
        dropout,
        input_dim: cfg.input_dim(),
        mlp_mask_dim: cfg.mlp_mask_dim(),
        n_params: cfg.n_params(),
        params,
        artifacts: Default::default(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Method, RoundingMode};
    use crate::data::synthetic::{generate, SyntheticSpec};

    fn tiny_exp(method: Method, use_runtime: bool) -> Experiment {
        Experiment {
            method,
            model: "tiny".into(),
            dataset: "tiny".into(),
            epochs: 1,
            use_runtime,
            lr_emb: 0.5,
            lr_delta: 1e-4,
            patience: 0,
            ..Experiment::default()
        }
    }

    #[test]
    fn builtin_entries_match_rust_layout() {
        for model in ["tiny", "avazu", "criteo", "avazu_d32", "criteo_d32"] {
            let e = builtin_entry(model).unwrap();
            assert!(e.layout_matches_rust(), "{model}");
            assert_eq!(e.umax, e.batch * e.fields);
        }
    }

    #[test]
    fn nn_path_trains_every_method() {
        let spec = SyntheticSpec::tiny(3);
        let ds = generate(&spec, 2000);
        let (train, val, _) = ds.split((0.8, 0.1, 0.1), 1);
        for method in [
            Method::Fp,
            Method::Lpt(RoundingMode::Sr),
            Method::Alpt(RoundingMode::Sr),
            Method::Lsq,
            Method::Pact,
            Method::Hashing,
            Method::Pruning,
        ] {
            let exp = tiny_exp(method, false);
            let mut tr =
                Trainer::new(exp, ds.schema.n_features()).unwrap();
            let res = tr.train(&train, &val, false).unwrap();
            assert!(res.best_auc > 0.4, "{method:?}: auc={}", res.best_auc);
            assert!(res.best_logloss.is_finite());
            assert_eq!(res.epochs_run, 1);
        }
    }

    #[test]
    fn nn_path_loss_decreases_over_epochs() {
        let spec = SyntheticSpec::tiny(5);
        let ds = generate(&spec, 4000);
        let (train, val, _) = ds.split((0.8, 0.1, 0.1), 1);
        let mut exp = tiny_exp(Method::Fp, false);
        exp.epochs = 3;
        let mut tr = Trainer::new(exp, ds.schema.n_features()).unwrap();
        let res = tr.train(&train, &val, false).unwrap();
        let first = res.history.first().unwrap().mean_loss;
        let last = res.history.last().unwrap().mean_loss;
        assert!(last < first, "loss did not decrease: {first} -> {last}");
    }

    #[test]
    fn auto_plan_resolves_before_any_table_is_built() {
        use crate::config::PrecisionPlan;
        let n = registry::schema_for(&tiny_exp(
            Method::Alpt(RoundingMode::Sr),
            false,
        ))
        .unwrap()
        .n_features();
        // mid-range budget: wide enough for >2-bit, too tight for all-16
        let budget = (n * 16) as u64;
        let mut exp = tiny_exp(Method::Alpt(RoundingMode::Sr), false);
        exp.bits = PrecisionPlan::parse(&format!("auto:{budget}")).unwrap();
        let tr = Trainer::new(exp, n).unwrap();
        assert!(
            tr.exp.bits.auto_budget().is_none(),
            "auto directive should be gone after resolution: {}",
            tr.exp.bits.key()
        );
        assert!(
            tr.store.infer_bytes() as u64 <= budget,
            "{} > {budget}",
            tr.store.infer_bytes()
        );

        // methods without packed tables reject the directive
        let mut bad = tiny_exp(Method::Fp, false);
        bad.bits = PrecisionPlan::parse("auto:1m").unwrap();
        let err = Trainer::new(bad, n).unwrap_err().to_string();
        assert!(err.contains("quantized"), "{err}");
    }

    #[test]
    fn replan_budget_migrates_at_the_epoch_boundary() {
        use crate::config::PrecisionPlan;
        let spec = SyntheticSpec::for_dataset("tiny", 42, 1.0).unwrap();
        let ds = generate(&spec, 3000);
        let (train, val, _) = ds.split((0.8, 0.1, 0.1), 42);
        let n = ds.schema.n_features();
        let d = builtin_entry("tiny").unwrap().emb_dim;

        let mut exp = tiny_exp(Method::Alpt(RoundingMode::Sr), false);
        exp.epochs = 2;
        exp.bits = PrecisionPlan::uniform(2);
        // generous budget: every field fits 16-bit codes + the Δ rows,
        // so the epoch-1 boundary upgrades the whole table
        exp.replan_budget = n * (2 * d + 4) + 64;
        let budget = exp.replan_budget as u64;

        let mut tr = Trainer::new(exp, n).unwrap();
        assert!(
            tr.store.as_grouped().is_some(),
            "re-planning runs build through the grouped store"
        );
        let res = tr.train(&train, &val, false).unwrap();
        assert_eq!(res.epochs_run, 2);
        assert_eq!(
            tr.exp.bits.as_uniform(),
            Some(16),
            "boundary replan should upgrade everything: {}",
            tr.exp.bits.key()
        );
        assert!(tr.store.infer_bytes() as u64 <= budget);
        assert!(res.best_auc > 0.4, "auc={}", res.best_auc);
        // counters were reset at the boundary: what is left is epoch 2's
        // update traffic alone (unique rows per step), which fits under
        // epoch 2's slot count — without the reset, epoch 1's updates
        // would push the total past it
        let total: u64 = tr
            .store
            .access_counts()
            .unwrap()
            .iter()
            .map(|&c| c as u64)
            .sum();
        let epoch2_slots = (res.history[1].steps
            * tr.entry.batch
            * tr.entry.fields) as u64;
        assert!(total > 0);
        assert!(total <= epoch2_slots, "{total} > {epoch2_slots}");
    }

    #[test]
    fn lr_schedule_applied() {
        let exp = Experiment {
            lr_milestones: vec![2],
            lr_gamma: 0.5,
            use_runtime: false,
            model: "tiny".into(),
            ..Experiment::default()
        };
        let tr = Trainer::new(exp, 100).unwrap();
        assert_eq!(tr.lr_scale(1), 1.0);
        assert_eq!(tr.lr_scale(2), 1.0);
        assert_eq!(tr.lr_scale(3), 0.5);
    }
}
