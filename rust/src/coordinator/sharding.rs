//! Sharded leader/worker simulation with communication accounting.
//!
//! The paper's motivation (§1): compressing embeddings at training time
//! cuts the cross-device traffic that dominates distributed CTR training.
//! [`ShardedStore`] range-partitions a store across `W` simulated workers;
//! every gather/update tallies the bytes a parameter-server deployment
//! would move:
//!
//! * leader → compute: the batch's unique rows, in the store's wire format
//!   (packed m-bit codes + Δ for LPT/ALPT, f32 rows otherwise);
//! * compute → leader: f32 row gradients (gradients are not quantized in
//!   the paper), plus one f32 Δ-gradient per row for ALPT.
//!
//! Byte counts are exact given the format; the time estimate divides by a
//! configurable link bandwidth.

use crate::config::{Experiment, Method};
use crate::data::batcher::Batch;
use crate::embedding::{build_store, EmbeddingStore};
use crate::util::rng::Pcg32;
use crate::util::threadpool::parallel_map;
use anyhow::Result;

/// Accumulated communication statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct CommStats {
    pub steps: u64,
    pub rows_moved: u64,
    pub bytes_down: u64, // leader -> compute (embedding rows)
    pub bytes_up: u64,   // compute -> leader (gradients)
}

impl CommStats {
    pub fn total_bytes(&self) -> u64 {
        self.bytes_down + self.bytes_up
    }

    /// Seconds on a link of `gbps` gigabits/s.
    pub fn seconds_at(&self, gbps: f64) -> f64 {
        (self.total_bytes() as f64 * 8.0) / (gbps * 1e9)
    }

    pub fn add(&mut self, other: &CommStats) {
        self.steps += other.steps;
        self.rows_moved += other.rows_moved;
        self.bytes_down += other.bytes_down;
        self.bytes_up += other.bytes_up;
    }
}

/// Per-row wire cost (bytes) of a method's embedding payload.
pub fn row_wire_bytes(method: Method, bits: u32, dim: usize) -> usize {
    match method {
        // packed codes + one f32 delta per row
        m if m.trains_quantized() => {
            (dim * bits as usize).div_ceil(8) + 4
        }
        // everything float-backed ships f32 rows
        _ => dim * 4,
    }
}

/// Gradient wire cost (bytes) per row: f32 grads (+ f32 dΔ for ALPT).
pub fn grad_wire_bytes(method: Method, dim: usize) -> usize {
    let base = dim * 4;
    match method {
        Method::Alpt(_) => base + 4,
        _ => base,
    }
}

/// Account one training step's traffic for a batch.
pub fn step_comm(
    method: Method,
    bits: u32,
    dim: usize,
    batch: &Batch,
) -> CommStats {
    let rows = batch.n_unique() as u64;
    CommStats {
        steps: 1,
        rows_moved: rows,
        bytes_down: rows * row_wire_bytes(method, bits, dim) as u64,
        bytes_up: rows * grad_wire_bytes(method, dim) as u64,
    }
}

/// A table sharded across `W` simulated workers (id % W), gathering in
/// parallel threads and accounting per-shard traffic.
pub struct ShardedStore {
    shards: Vec<Box<dyn EmbeddingStore>>,
    method: Method,
    bits: u32,
    dim: usize,
    pub n_workers: usize,
    pub stats: CommStats,
}

impl ShardedStore {
    /// Build `n_workers` shard stores over id-partitioned feature spaces
    /// (each worker holds ~n/W rows).
    pub fn new(
        exp: &Experiment,
        n_features: usize,
        dim: usize,
        n_workers: usize,
    ) -> Result<Self> {
        assert!(n_workers >= 1);
        let shard_features = n_features.div_ceil(n_workers);
        let shards = (0..n_workers)
            .map(|w| {
                let mut rng =
                    Pcg32::new(exp.seed.wrapping_add(w as u64), 0x5A4D);
                build_store(exp, shard_features, dim, &mut rng)
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            shards,
            method: exp.method,
            // wire-cost accounting is a uniform-width simulation; mixed
            // plans fall back to their default width here
            bits: exp.bits.default_bits(),
            dim,
            n_workers,
            stats: CommStats::default(),
        })
    }

    pub fn shard(&self, w: usize) -> &dyn EmbeddingStore {
        self.shards[w].as_ref()
    }

    /// Total table bytes across shards.
    pub fn train_bytes(&self) -> usize {
        self.shards.iter().map(|s| s.train_bytes()).sum()
    }

    /// Parallel gather across shards: each worker extracts its rows, the
    /// leader reassembles (and the traffic is tallied).
    pub fn gather(&mut self, ids: &[u32], out: &mut [f32]) {
        let n_workers = self.n_workers;
        let dim = self.dim;
        // per-worker (positions, local ids)
        let mut assign: Vec<(Vec<usize>, Vec<u32>)> =
            vec![(Vec::new(), Vec::new()); n_workers];
        for (pos, &id) in ids.iter().enumerate() {
            let w = (id as usize) % n_workers;
            assign[w].0.push(pos);
            assign[w].1.push(id / n_workers as u32);
        }
        let shards = &self.shards;
        let gathered: Vec<Vec<f32>> = parallel_map(n_workers, n_workers, |w| {
            let (_, locals) = &assign[w];
            let mut buf = vec![0.0f32; locals.len() * dim];
            if !locals.is_empty() {
                shards[w].gather(locals, &mut buf);
            }
            buf
        });
        for (w, buf) in gathered.into_iter().enumerate() {
            for (k, &pos) in assign[w].0.iter().enumerate() {
                out[pos * dim..(pos + 1) * dim]
                    .copy_from_slice(&buf[k * dim..(k + 1) * dim]);
            }
        }
        self.stats.add(&CommStats {
            steps: 1,
            rows_moved: ids.len() as u64,
            bytes_down: (ids.len()
                * row_wire_bytes(self.method, self.bits, dim))
                as u64,
            bytes_up: (ids.len() * grad_wire_bytes(self.method, dim)) as u64,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RoundingMode;
    use crate::data::batcher::make_batch;
    use crate::data::{Dataset, Schema};

    fn toy_batch() -> Batch {
        let schema = Schema::new(vec![8, 8]);
        let ds = Dataset {
            schema,
            features: vec![0, 8, 1, 9, 2, 10, 0, 8],
            labels: vec![1, 0, 1, 0],
        };
        make_batch(&ds, &[0, 1, 2, 3], 4)
    }

    #[test]
    fn wire_bytes_follow_bit_width() {
        let d = 16;
        let fp = row_wire_bytes(Method::Fp, 32, d);
        assert_eq!(fp, 64);
        let alpt8 =
            row_wire_bytes(Method::Alpt(RoundingMode::Sr), 8, d);
        assert_eq!(alpt8, 16 + 4);
        let alpt2 =
            row_wire_bytes(Method::Alpt(RoundingMode::Sr), 2, d);
        assert_eq!(alpt2, 4 + 4);
        // QAT ships fp rows at train time
        assert_eq!(row_wire_bytes(Method::Lsq, 8, d), 64);
    }

    #[test]
    fn step_comm_counts_uniques_not_slots() {
        let batch = toy_batch();
        assert_eq!(batch.n_unique(), 6); // ids {0,8,1,9,2,10}
        let s = step_comm(Method::Fp, 32, 4, &batch);
        assert_eq!(s.rows_moved, 6);
        assert_eq!(s.bytes_down, 6 * 16);
        assert_eq!(s.bytes_up, 6 * 16);
    }

    #[test]
    fn quantized_comm_smaller_than_fp() {
        let batch = toy_batch();
        let fp = step_comm(Method::Fp, 32, 16, &batch);
        let q8 =
            step_comm(Method::Alpt(RoundingMode::Sr), 8, 16, &batch);
        let q2 =
            step_comm(Method::Alpt(RoundingMode::Sr), 2, 16, &batch);
        assert!(q8.bytes_down < fp.bytes_down);
        assert!(q2.bytes_down < q8.bytes_down);
        // uplink (f32 grads) identical up to the delta-grad float
        assert!(q8.bytes_up >= fp.bytes_up);
    }

    #[test]
    fn seconds_scale_with_bandwidth() {
        let mut s = CommStats::default();
        s.bytes_down = 1_000_000_000;
        assert!((s.seconds_at(8.0) - 1.0).abs() < 1e-9);
        assert!((s.seconds_at(80.0) - 0.1).abs() < 1e-9);
    }

    #[test]
    fn sharded_gather_matches_single_store() {
        use crate::config::Experiment;
        let exp = Experiment {
            method: Method::Fp,
            model: "tiny".into(),
            use_runtime: false,
            ..Experiment::default()
        };
        let (n_features, dim) = (64, 8);
        let mut sharded =
            ShardedStore::new(&exp, n_features, dim, 4).unwrap();
        let ids: Vec<u32> = vec![0, 5, 17, 33, 63, 2];
        let mut out = vec![0.0f32; ids.len() * dim];
        sharded.gather(&ids, &mut out);
        // every row must be that worker's row for local id
        for (i, &id) in ids.iter().enumerate() {
            let w = (id as usize) % 4;
            let local = id / 4;
            let mut want = vec![0.0f32; dim];
            sharded.shard(w).gather(&[local], &mut want);
            assert_eq!(&out[i * dim..(i + 1) * dim], &want[..], "id {id}");
        }
        assert_eq!(sharded.stats.steps, 1);
        assert_eq!(sharded.stats.rows_moved, 6);
    }
}
