//! Row partitioning + communication accounting for distributed training.
//!
//! The paper's motivation (§1): compressing embeddings at training time
//! cuts the cross-device traffic that dominates distributed CTR training.
//! [`RowPartition`] is the one partition function both the wire path
//! (`coordinator::net` / `embedding::RemoteStore`) and checkpoint
//! resharding share: global row id → owning shard, shard-local row id,
//! and back. Checkpoints always persist rows in canonical *global* order,
//! so a table trained on N workers reshards transparently onto M (or
//! onto one process) — the partition is a pure function of `(id,
//! n_shards)` and never appears in the file format.
//!
//! [`CommStats`] / [`step_comm`] stay as the analytical pricing layer on
//! top: what a parameter-server deployment moves per step, given the
//! store's wire format —
//!
//! * coordinator ← worker: the batch's unique rows, in the store's wire
//!   format (packed m-bit codes + Δ for LPT/ALPT, f32 rows otherwise);
//! * coordinator → worker: f32 row gradients (gradients are not
//!   quantized in the paper), plus one f32 Δ-gradient per row for ALPT.
//!
//! Byte counts are exact given the format; the time estimate divides by a
//! configurable link bandwidth. `benches/comm.rs` compares this model
//! against measured bytes from the real frame encoder.

use crate::config::Method;
use crate::data::batcher::Batch;

/// Accumulated communication statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct CommStats {
    pub steps: u64,
    pub rows_moved: u64,
    pub bytes_down: u64, // coordinator <- workers (embedding rows)
    pub bytes_up: u64,   // coordinator -> workers (gradients)
}

impl CommStats {
    pub fn total_bytes(&self) -> u64 {
        self.bytes_down + self.bytes_up
    }

    /// Seconds on a link of `gbps` gigabits/s.
    pub fn seconds_at(&self, gbps: f64) -> f64 {
        (self.total_bytes() as f64 * 8.0) / (gbps * 1e9)
    }

    pub fn add(&mut self, other: &CommStats) {
        self.steps += other.steps;
        self.rows_moved += other.rows_moved;
        self.bytes_down += other.bytes_down;
        self.bytes_up += other.bytes_up;
    }
}

/// Per-row wire cost (bytes) of a method's embedding payload.
pub fn row_wire_bytes(method: Method, bits: u32, dim: usize) -> usize {
    match method {
        // packed codes + one f32 delta per row
        m if m.trains_quantized() => {
            (dim * bits as usize).div_ceil(8) + 4
        }
        // everything float-backed ships f32 rows
        _ => dim * 4,
    }
}

/// Gradient wire cost (bytes) per row: f32 grads (+ f32 dΔ for ALPT).
pub fn grad_wire_bytes(method: Method, dim: usize) -> usize {
    let base = dim * 4;
    match method {
        Method::Alpt(_) => base + 4,
        _ => base,
    }
}

/// Account one training step's traffic for a batch.
pub fn step_comm(
    method: Method,
    bits: u32,
    dim: usize,
    batch: &Batch,
) -> CommStats {
    let rows = batch.n_unique() as u64;
    CommStats {
        steps: 1,
        rows_moved: rows,
        bytes_down: rows * row_wire_bytes(method, bits, dim) as u64,
        bytes_up: rows * grad_wire_bytes(method, dim) as u64,
    }
}

/// The partition of `n_rows` global row ids across `n_shards` workers:
/// shard `s` owns the ids congruent to `s` mod `n_shards`, and its local
/// row `l` is global id `s + l·n_shards` — so every shard's local ids are
/// contiguous `0..shard_rows(s)`, which keeps worker tables dense and
/// LOAD/checkpoint streaming chunkable.
///
/// The mapping is a pure function of `(id, n_shards)`; nothing about it
/// is persisted. Checkpoints store rows in global order, so resharding
/// N → M is just re-evaluating this function at load time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RowPartition {
    n_rows: usize,
    n_shards: usize,
}

impl RowPartition {
    pub fn new(n_rows: usize, n_shards: usize) -> Self {
        assert!(n_shards >= 1, "a partition needs at least one shard");
        Self { n_rows, n_shards }
    }

    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// Which shard owns global row `id`.
    #[inline]
    pub fn shard_of(&self, id: u32) -> usize {
        id as usize % self.n_shards
    }

    /// The shard-local row id of global row `id` (on `shard_of(id)`).
    #[inline]
    pub fn local_of(&self, id: u32) -> u32 {
        id / self.n_shards as u32
    }

    /// Inverse of (`shard_of`, `local_of`): the global id of `shard`'s
    /// local row `local`.
    #[inline]
    pub fn global_of(&self, shard: usize, local: u32) -> u32 {
        (shard + local as usize * self.n_shards) as u32
    }

    /// How many rows `shard` owns (locals are `0..shard_rows(shard)`).
    pub fn shard_rows(&self, shard: usize) -> usize {
        debug_assert!(shard < self.n_shards);
        (self.n_rows + self.n_shards - 1 - shard) / self.n_shards
    }

    /// Split a batch's ids per shard: for each shard, the batch
    /// positions it serves and the *global* ids to request (the wire
    /// always carries global ids; workers map to locals themselves, so
    /// both ends agree on the id space the SR streams are keyed by).
    pub fn split(&self, ids: &[u32]) -> Vec<(Vec<usize>, Vec<u32>)> {
        let mut assign: Vec<(Vec<usize>, Vec<u32>)> =
            vec![(Vec::new(), Vec::new()); self.n_shards];
        for (pos, &id) in ids.iter().enumerate() {
            let s = self.shard_of(id);
            assign[s].0.push(pos);
            assign[s].1.push(id);
        }
        assign
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RoundingMode;
    use crate::data::batcher::make_batch;
    use crate::data::{Dataset, Schema};

    fn toy_batch() -> Batch {
        let schema = Schema::new(vec![8, 8]);
        let ds = Dataset {
            schema,
            features: vec![0, 8, 1, 9, 2, 10, 0, 8],
            labels: vec![1, 0, 1, 0],
        };
        make_batch(&ds, &[0, 1, 2, 3], 4)
    }

    #[test]
    fn wire_bytes_follow_bit_width() {
        let d = 16;
        let fp = row_wire_bytes(Method::Fp, 32, d);
        assert_eq!(fp, 64);
        let alpt8 =
            row_wire_bytes(Method::Alpt(RoundingMode::Sr), 8, d);
        assert_eq!(alpt8, 16 + 4);
        let alpt2 =
            row_wire_bytes(Method::Alpt(RoundingMode::Sr), 2, d);
        assert_eq!(alpt2, 4 + 4);
        // QAT ships fp rows at train time
        assert_eq!(row_wire_bytes(Method::Lsq, 8, d), 64);
    }

    #[test]
    fn step_comm_counts_uniques_not_slots() {
        let batch = toy_batch();
        assert_eq!(batch.n_unique(), 6); // ids {0,8,1,9,2,10}
        let s = step_comm(Method::Fp, 32, 4, &batch);
        assert_eq!(s.rows_moved, 6);
        assert_eq!(s.bytes_down, 6 * 16);
        assert_eq!(s.bytes_up, 6 * 16);
    }

    #[test]
    fn quantized_comm_smaller_than_fp() {
        let batch = toy_batch();
        let fp = step_comm(Method::Fp, 32, 16, &batch);
        let q8 =
            step_comm(Method::Alpt(RoundingMode::Sr), 8, 16, &batch);
        let q2 =
            step_comm(Method::Alpt(RoundingMode::Sr), 2, 16, &batch);
        assert!(q8.bytes_down < fp.bytes_down);
        assert!(q2.bytes_down < q8.bytes_down);
        // uplink (f32 grads) identical up to the delta-grad float
        assert!(q8.bytes_up >= fp.bytes_up);
    }

    #[test]
    fn seconds_scale_with_bandwidth() {
        let mut s = CommStats::default();
        s.bytes_down = 1_000_000_000;
        assert!((s.seconds_at(8.0) - 1.0).abs() < 1e-9);
        assert!((s.seconds_at(80.0) - 0.1).abs() < 1e-9);
    }

    #[test]
    fn partition_roundtrips_every_id() {
        for n_shards in [1usize, 2, 3, 4, 7] {
            let part = RowPartition::new(100, n_shards);
            for id in 0..100u32 {
                let s = part.shard_of(id);
                let l = part.local_of(id);
                assert!(s < n_shards);
                assert_eq!(part.global_of(s, l), id, "W={n_shards} id={id}");
                assert!(
                    (l as usize) < part.shard_rows(s),
                    "W={n_shards} id={id}: local {l} out of range"
                );
            }
        }
    }

    #[test]
    fn shard_rows_cover_the_table_exactly() {
        for (n, w) in [(10usize, 4usize), (100, 7), (65_536, 3), (5, 8)] {
            let part = RowPartition::new(n, w);
            let total: usize = (0..w).map(|s| part.shard_rows(s)).sum();
            assert_eq!(total, n, "n={n} W={w}");
            // locals are dense: every (shard, local) maps into [0, n)
            for s in 0..w {
                for l in 0..part.shard_rows(s) as u32 {
                    assert!((part.global_of(s, l) as usize) < n);
                }
            }
        }
    }

    #[test]
    fn partition_is_stable_under_resharding() {
        // the same global id keeps its identity across shard counts —
        // resharding only re-evaluates the pure function, so a
        // checkpoint written in global order reloads anywhere
        let n = 1000;
        for id in [0u32, 1, 13, 999] {
            for w in [1usize, 2, 5] {
                let p = RowPartition::new(n, w);
                assert_eq!(p.global_of(p.shard_of(id), p.local_of(id)), id);
            }
        }
    }

    #[test]
    fn split_preserves_positions_and_globals() {
        let part = RowPartition::new(64, 4);
        let ids: Vec<u32> = vec![0, 5, 17, 33, 63, 2];
        let assign = part.split(&ids);
        let mut seen = 0usize;
        for (s, (positions, globals)) in assign.iter().enumerate() {
            assert_eq!(positions.len(), globals.len());
            for (&pos, &g) in positions.iter().zip(globals) {
                assert_eq!(ids[pos], g, "shard {s}");
                assert_eq!(part.shard_of(g), s);
            }
            seen += positions.len();
        }
        assert_eq!(seen, ids.len());
    }
}
