//! Checkpoint-backed offline serving: load → validate → batched
//! inference over the request stream a checkpoint's experiment echo
//! describes. Used by the `alpt serve` subcommand (without `--listen`)
//! and `examples/serve.rs`.
//!
//! The inference body itself lives in the shared
//! [`crate::serve::InferenceEngine`] — the same `score` every online
//! entry point uses (HTTP server, trainer eval) — so the offline loop
//! here is only stream assembly plus metric accounting. No training
//! step, no PJRT requirement.

use std::path::Path;
use std::time::Instant;

use anyhow::{bail, ensure, Result};

use crate::config::Experiment;
use crate::data::batcher::{Batch, Batcher, StreamBatcher, Tail};
use crate::data::registry::{self, DataSource, DatasetSpec, RecordStream};
use crate::data::synthetic::{generate, SyntheticSpec};
use crate::metrics::{EvalAccumulator, LatencyHistogram, StreamingEval};
use crate::serve::InferenceEngine;

/// Everything a caller needs to report on a serving run.
pub struct ServeReport {
    pub method: &'static str,
    pub n_features: usize,
    pub dim: usize,
    /// Bytes to ship the restored table for inference.
    pub infer_bytes: usize,
    /// The fp32 baseline for the same geometry.
    pub fp_bytes: usize,
    pub batch_size: usize,
    pub requests: usize,
    pub auc: f64,
    pub logloss: f64,
    /// Per-batch serving latencies (p50/p95/p99 via
    /// [`LatencyHistogram::percentile_ms`]; never empty).
    pub latency: LatencyHistogram,
    /// Checkpoint load + validation time in milliseconds.
    pub load_ms: f64,
    /// One-time request-stream setup time in milliseconds (dataset
    /// regeneration or source open + split), measured identically for
    /// both dataset families and excluded from per-request serving cost.
    pub data_ms: f64,
    /// Data-quality warnings from the request source (e.g. malformed
    /// lines skipped in a streamed file); empty when clean. Callers
    /// should surface these — metrics over silently-dropped records are
    /// misleading.
    pub warnings: Vec<String>,
    /// The experiment echo the checkpoint carried.
    pub exp: Experiment,
}

impl ServeReport {
    pub fn batches(&self) -> usize {
        self.latency.count() as usize
    }

    pub fn total_ms(&self) -> f64 {
        self.latency.total_ms()
    }

    pub fn requests_per_sec(&self) -> f64 {
        self.requests as f64 / (self.total_ms() / 1e3).max(1e-9)
    }

    pub fn p50_ms(&self) -> f64 {
        self.latency.percentile_ms(50.0)
    }

    pub fn p95_ms(&self) -> f64 {
        self.latency.percentile_ms(95.0)
    }

    pub fn p99_ms(&self) -> f64 {
        self.latency.percentile_ms(99.0)
    }
}

/// One held-out record with the logit the offline path scored it to —
/// the ground truth the CI online-serve leg replays over HTTP.
pub struct SampleRequest {
    pub features: Vec<u32>,
    pub logit: f32,
}

/// Load `path`, rebuild the request stream its experiment echo
/// describes, and serve up to `max_batches` test batches through the
/// shared [`InferenceEngine`]. Errors (rather than panicking) on
/// geometry mismatches and on runs that produce zero batches.
pub fn serve_checkpoint(
    path: &Path,
    max_batches: usize,
) -> Result<ServeReport> {
    let engine = InferenceEngine::from_checkpoint(path)?;
    serve_with_engine(&engine, max_batches)
}

/// The offline serving loop over an already-restored engine.
pub fn serve_with_engine(
    engine: &InferenceEngine,
    max_batches: usize,
) -> Result<ServeReport> {
    let exp = engine.exp().clone();
    let b = engine.batch_size();
    let latency = LatencyHistogram::new();
    // the one shared inference body: every batch of either dataset
    // family goes through InferenceEngine::score
    let serve_batch = |batch: &Batch| -> Vec<f32> {
        let t = Instant::now();
        let logits = engine.score(batch);
        latency.record_ms(t.elapsed().as_secs_f64() * 1e3);
        logits
    };
    // request-stream setup is timed from here to just before the first
    // batch is assembled — the same boundary for both families
    let t1 = Instant::now();
    let data_ms_of = |t: Instant| t.elapsed().as_secs_f64() * 1e3;
    let (auc, logloss, requests, data_ms, warnings) =
        match DatasetSpec::parse(&exp.dataset) {
            DatasetSpec::Synthetic(name) => {
                let spec = SyntheticSpec::for_dataset(
                    &name,
                    exp.seed,
                    exp.vocab_scale,
                )?;
                let ds = generate(&spec, exp.n_samples);
                // same rule as registry::ensure_compat: the table may be
                // larger than the schema (warm-start), never smaller
                ensure!(
                    ds.schema.n_features() <= engine.n_features(),
                    "dataset {} needs {} feature rows, the checkpointed \
                     table holds {}",
                    spec.name,
                    ds.schema.n_features(),
                    engine.n_features()
                );
                let (_, _, test) = ds.split((0.8, 0.1, 0.1), exp.seed);
                let data_ms = data_ms_of(t1);
                let mut acc = EvalAccumulator::new();
                for batch in
                    Batcher::new(&test, b, None, false).take(max_batches)
                {
                    let logits = serve_batch(&batch);
                    acc.push(&logits, &batch.labels, batch.valid);
                }
                (acc.auc(), acc.logloss(), acc.len(), data_ms, Vec::new())
            }
            DatasetSpec::SyntheticStream(_) | DatasetSpec::CriteoFile(_) => {
                let source = registry::open_source(&exp)?;
                registry::ensure_compat(
                    source.as_ref(),
                    &exp.model,
                    engine.fields(),
                    engine.n_features(),
                )?;
                let stream = registry::val_stream(source.as_ref(), &exp)?;
                let data_ms = data_ms_of(t1);
                let mut acc = StreamingEval::new();
                let batches = StreamBatcher::new(
                    stream,
                    engine.fields(),
                    b,
                    Tail::Pad,
                );
                for item in batches.take(max_batches) {
                    let batch = item?;
                    let logits = serve_batch(&batch);
                    acc.push(&logits, &batch.labels, batch.valid);
                }
                (
                    acc.auc(),
                    acc.logloss(),
                    acc.len(),
                    data_ms,
                    source.warnings(),
                )
            }
        };
    if latency.count() == 0 {
        bail!("no test batches to serve (max_batches or split too small)");
    }

    Ok(ServeReport {
        method: engine.method_name(),
        n_features: engine.n_features(),
        dim: engine.dim(),
        infer_bytes: engine.infer_bytes(),
        fp_bytes: engine.fp_bytes(),
        batch_size: b,
        requests,
        auc,
        logloss,
        latency,
        load_ms: engine.load_ms(),
        data_ms,
        warnings,
        exp,
    })
}

/// Score the first `n` held-out records of `path`'s request stream
/// individually — features plus the offline logit. `alpt serve
/// --dump-requests N` prints these as JSON lines; the CI online-serve
/// leg replays them over HTTP and asserts the scores match (per-record
/// logits are independent of batch composition, so the offline and
/// micro-batched paths agree bit for bit).
pub fn sample_requests(
    path: &Path,
    n: usize,
) -> Result<Vec<SampleRequest>> {
    ensure!(n > 0, "need at least one request to sample");
    let engine = InferenceEngine::from_checkpoint(path)?;
    let exp = engine.exp().clone();
    let mut out = Vec::new();
    let mut push = |features: &[u32]| -> Result<()> {
        let logit = engine.score_records(features)?[0];
        out.push(SampleRequest { features: features.to_vec(), logit });
        Ok(())
    };
    match DatasetSpec::parse(&exp.dataset) {
        DatasetSpec::Synthetic(name) => {
            let spec = SyntheticSpec::for_dataset(
                &name,
                exp.seed,
                exp.vocab_scale,
            )?;
            let ds = generate(&spec, exp.n_samples);
            let (_, _, test) = ds.split((0.8, 0.1, 0.1), exp.seed);
            for i in 0..n.min(test.n_samples()) {
                push(test.sample(i))?;
            }
        }
        DatasetSpec::SyntheticStream(_) | DatasetSpec::CriteoFile(_) => {
            let source = registry::open_source(&exp)?;
            registry::ensure_compat(
                source.as_ref(),
                &exp.model,
                engine.fields(),
                engine.n_features(),
            )?;
            let mut stream = registry::val_stream(source.as_ref(), &exp)?;
            let mut buf = vec![0u32; engine.fields()];
            // count separately: `push` holds the mutable borrow of `out`,
            // so the loop condition must not read out.len()
            let mut taken = 0usize;
            while taken < n {
                match stream.next_record(&mut buf)? {
                    Some(_) => {
                        push(&buf)?;
                        taken += 1;
                    }
                    None => break,
                }
            }
        }
    }
    ensure!(!out.is_empty(), "request stream held no records to sample");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::save_store;
    use crate::config::Method;
    use crate::coordinator::Trainer;
    use crate::data::Schema;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("alpt_serve_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn tiny_trained_ckpt(name: &str) -> std::path::PathBuf {
        let exp = Experiment {
            method: Method::Lpt(crate::config::RoundingMode::Sr),
            model: "tiny".into(),
            dataset: "tiny".into(),
            n_samples: 2000,
            use_runtime: false,
            threads: 1,
            ..Experiment::default()
        };
        let spec = SyntheticSpec::tiny(exp.seed);
        let n = Schema::new(spec.vocabs.clone()).n_features();
        let mut tr = Trainer::new(exp, n).unwrap();
        let path = tmp(name);
        tr.save_checkpoint(&path).unwrap();
        path
    }

    fn streaming_trained_ckpt(name: &str) -> std::path::PathBuf {
        let exp = Experiment {
            method: Method::Lpt(crate::config::RoundingMode::Sr),
            model: "tiny".into(),
            dataset: "synthetic:tiny".into(),
            n_samples: 2000,
            use_runtime: false,
            threads: 1,
            ..Experiment::default()
        };
        let n = registry::schema_for(&exp).unwrap().n_features();
        let mut tr = Trainer::new(exp, n).unwrap();
        let path = tmp(name);
        tr.save_checkpoint(&path).unwrap();
        path
    }

    #[test]
    fn serves_from_a_trainer_checkpoint() {
        let path = tiny_trained_ckpt("serve_ok.ckpt");
        let report = serve_checkpoint(&path, 4).unwrap();
        assert_eq!(report.method, "LPT(SR)");
        assert_eq!(report.batches(), 4);
        // requests counts un-padded samples only
        assert!(
            report.requests > 0
                && report.requests <= 4 * report.batch_size,
            "requests={}",
            report.requests
        );
        assert!(report.auc.is_finite() && report.logloss.is_finite());
        assert!(report.infer_bytes < report.fp_bytes);
        assert!(report.requests_per_sec() > 0.0);
        // percentile reporting comes straight from the histogram
        assert!(report.p50_ms() > 0.0);
        assert!(report.p50_ms() <= report.p95_ms() * 1.0001);
        assert!(report.p95_ms() <= report.p99_ms() * 1.0001);
        assert!(report.total_ms() > 0.0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn zero_batches_is_an_error_not_a_panic() {
        let path = tiny_trained_ckpt("serve_zero.ckpt");
        let err = format!("{:#}", serve_checkpoint(&path, 0).unwrap_err());
        assert!(err.contains("no test batches"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn data_ms_accounting_is_symmetric_across_families() {
        // both dataset families time request-stream setup with the same
        // boundary (engine load excluded, first batch assembly excluded)
        // and report it once, not per batch
        let syn = tiny_trained_ckpt("serve_data_syn.ckpt");
        let stream = streaming_trained_ckpt("serve_data_stream.ckpt");
        for path in [&syn, &stream] {
            let one = serve_checkpoint(path, 1).unwrap();
            let four = serve_checkpoint(path, 4).unwrap();
            for r in [&one, &four] {
                assert!(
                    r.data_ms.is_finite() && r.data_ms >= 0.0,
                    "data_ms={}",
                    r.data_ms
                );
                assert!(r.load_ms > 0.0, "load_ms={}", r.load_ms);
            }
            // serving more batches grows served latency samples, not the
            // one-time data setup bucket
            assert_eq!(one.batches(), 1);
            assert_eq!(four.batches(), 4);
            // deterministic request stream: same batches → same metrics
            let again = serve_checkpoint(path, 4).unwrap();
            assert_eq!(four.auc.to_bits(), again.auc.to_bits());
            assert_eq!(four.requests, again.requests);
        }
        std::fs::remove_file(&syn).ok();
        std::fs::remove_file(&stream).ok();
    }

    #[test]
    fn sample_requests_match_serving_path() {
        for (name, streaming) in
            [("dump_syn.ckpt", false), ("dump_stream.ckpt", true)]
        {
            let path = if streaming {
                streaming_trained_ckpt(name)
            } else {
                tiny_trained_ckpt(name)
            };
            let reqs = sample_requests(&path, 5).unwrap();
            assert_eq!(reqs.len(), 5);
            let engine = InferenceEngine::from_checkpoint(&path).unwrap();
            for r in &reqs {
                assert_eq!(r.features.len(), engine.fields());
                let z = engine.score_records(&r.features).unwrap()[0];
                assert_eq!(z.to_bits(), r.logit.to_bits());
            }
            std::fs::remove_file(&path).ok();
        }
    }

    #[test]
    fn serves_a_mixed_precision_checkpoint() {
        // a per-field plan builds a grouped store whose v2 checkpoint
        // must load and serve through the identical path
        let exp = Experiment {
            method: Method::Alpt(crate::config::RoundingMode::Sr),
            bits: crate::config::PrecisionPlan::parse(
                "f0:4,f1:8,default:2",
            )
            .unwrap(),
            model: "tiny".into(),
            dataset: "synthetic:tiny".into(),
            n_samples: 2000,
            use_runtime: false,
            threads: 1,
            ..Experiment::default()
        };
        let n = crate::data::registry::schema_for(&exp)
            .unwrap()
            .n_features();
        let mut tr = Trainer::new(exp, n).unwrap();
        let path = tmp("serve_mixed.ckpt");
        tr.save_checkpoint(&path).unwrap();
        let report = serve_checkpoint(&path, 4).unwrap();
        assert_eq!(report.method, "ALPT(SR)[mixed]");
        assert_eq!(report.n_features, n);
        assert!(report.auc.is_finite() && report.logloss.is_finite());
        assert!(
            report.infer_bytes < report.fp_bytes,
            "mixed table must still compress: {} vs {}",
            report.infer_bytes,
            report.fp_bytes
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn store_only_checkpoint_without_dense_is_rejected() {
        let exp = Experiment {
            method: Method::Fp,
            use_runtime: false,
            ..Experiment::default()
        };
        let mut rng = crate::util::rng::Pcg32::seeded(3);
        let store =
            crate::embedding::build_store(&exp, 40, 8, &mut rng).unwrap();
        let path = tmp("no_dense.ckpt");
        save_store(&path, store.as_ref(), &exp).unwrap();
        let err = format!("{:#}", serve_checkpoint(&path, 1).unwrap_err());
        assert!(err.contains("dense"), "{err}");
        std::fs::remove_file(&path).ok();
    }
}
