//! Checkpoint-backed serving: one shared load → validate → batched
//! inference loop used by both the `alpt serve` subcommand and
//! `examples/serve.rs`, so the two entry points cannot drift apart.
//!
//! The loop is strictly inference-only: gather de-quantized rows from
//! the restored store, run the Rust DCN forward, accumulate metrics and
//! per-batch latencies. No training step, no PJRT requirement.

use std::path::Path;
use std::time::Instant;

use anyhow::{bail, ensure, Result};

use super::trainer::builtin_entry;
use crate::checkpoint::{dense_params, load_store, Checkpoint};
use crate::config::Experiment;
use crate::data::batcher::{Batch, Batcher, StreamBatcher, Tail};
use crate::data::registry::{self, DataSource, DatasetSpec};
use crate::data::synthetic::{generate, SyntheticSpec};
use crate::embedding::fp_bytes;
use crate::metrics::{EvalAccumulator, StreamingEval};
use crate::nn::Dcn;

/// Everything a caller needs to report on a serving run.
pub struct ServeReport {
    pub method: &'static str,
    pub n_features: usize,
    pub dim: usize,
    /// Bytes to ship the restored table for inference.
    pub infer_bytes: usize,
    /// The fp32 baseline for the same geometry.
    pub fp_bytes: usize,
    pub batch_size: usize,
    pub requests: usize,
    pub auc: f64,
    pub logloss: f64,
    /// Per-batch latencies in milliseconds (never empty).
    pub latencies_ms: Vec<f64>,
    /// Checkpoint load + validation time in milliseconds.
    pub load_ms: f64,
    /// One-time synthetic request-stream regeneration in milliseconds
    /// (not part of per-request serving cost).
    pub data_ms: f64,
    /// Data-quality warnings from the request source (e.g. malformed
    /// lines skipped in a streamed file); empty when clean. Callers
    /// should surface these — metrics over silently-dropped records are
    /// misleading.
    pub warnings: Vec<String>,
    /// The experiment echo the checkpoint carried.
    pub exp: Experiment,
}

impl ServeReport {
    pub fn batches(&self) -> usize {
        self.latencies_ms.len()
    }

    pub fn total_ms(&self) -> f64 {
        self.latencies_ms.iter().sum()
    }

    pub fn requests_per_sec(&self) -> f64 {
        self.requests as f64 / (self.total_ms() / 1e3).max(1e-9)
    }
}

/// Load `path`, rebuild the request stream its experiment echo
/// describes, and serve up to `max_batches` test batches through the
/// Rust nn path. Errors (rather than panicking) on geometry mismatches
/// and on runs that produce zero batches.
pub fn serve_checkpoint(
    path: &Path,
    max_batches: usize,
) -> Result<ServeReport> {
    let t0 = Instant::now();
    let ckpt = Checkpoint::read(path)?;
    let (store, exp) = load_store(&ckpt)?;
    let dense = dense_params(&ckpt)?;
    let entry = builtin_entry(&exp.model)?;
    ensure!(
        dense.len() == entry.n_params,
        "checkpoint holds {} dense params, model {:?} expects {}",
        dense.len(),
        exp.model,
        entry.n_params
    );
    ensure!(
        store.dim() == entry.emb_dim,
        "checkpoint embedding dim {} does not match model {:?} (dim {})",
        store.dim(),
        exp.model,
        entry.emb_dim
    );
    let dcn = Dcn::new(entry.dcn_config());
    let load_ms = t0.elapsed().as_secs_f64() * 1e3;

    // rebuild the request stream the training run's experiment echo
    // describes: synthetic specs regenerate in memory and serve the test
    // split (exact AUC over the full score set); streaming specs
    // (criteo:<path> / synthetic:*) serve the held-out split straight
    // off the source with the fixed-memory accumulator, so serving a
    // full Criteo dump never holds the split in memory. The one-time
    // setup is reported separately as `data_ms`.
    let (umax, d, b) = (entry.umax, entry.emb_dim, entry.batch);
    let mut emb = vec![0.0f32; umax * d];
    let mut latencies = Vec::new();
    // one shared inference body, so the two dataset families cannot
    // drift apart (same pattern as Trainer::batch_logits)
    let mut serve_batch = |batch: &Batch| -> Vec<f32> {
        let t = Instant::now();
        let n_u = batch.unique.len();
        emb[n_u * d..].fill(0.0);
        store.gather(&batch.unique, &mut emb[..n_u * d]);
        let logits = dcn.infer(&emb, &batch.idx, &dense);
        latencies.push(t.elapsed().as_secs_f64() * 1e3);
        logits
    };
    let t1 = Instant::now();
    let (auc, logloss, requests, data_ms, warnings) =
        match DatasetSpec::parse(&exp.dataset) {
            DatasetSpec::Synthetic(name) => {
                let spec = SyntheticSpec::for_dataset(
                    &name,
                    exp.seed,
                    exp.vocab_scale,
                )?;
                let ds = generate(&spec, exp.n_samples);
                // same rule as registry::ensure_compat: the table may be
                // larger than the schema (warm-start), never smaller
                ensure!(
                    ds.schema.n_features() <= store.n_features(),
                    "dataset {} needs {} feature rows, the checkpointed \
                     table holds {}",
                    spec.name,
                    ds.schema.n_features(),
                    store.n_features()
                );
                let (_, _, test) = ds.split((0.8, 0.1, 0.1), exp.seed);
                let data_ms = t1.elapsed().as_secs_f64() * 1e3;
                let mut acc = EvalAccumulator::new();
                for batch in
                    Batcher::new(&test, b, None, false).take(max_batches)
                {
                    let logits = serve_batch(&batch);
                    acc.push(&logits, &batch.labels, batch.valid);
                }
                (acc.auc(), acc.logloss(), acc.len(), data_ms, Vec::new())
            }
            DatasetSpec::SyntheticStream(_) | DatasetSpec::CriteoFile(_) => {
                let source = registry::open_source(&exp)?;
                registry::ensure_compat(
                    source.as_ref(),
                    &exp.model,
                    entry.fields,
                    store.n_features(),
                )?;
                let stream = registry::val_stream(source.as_ref(), &exp)?;
                let data_ms = t1.elapsed().as_secs_f64() * 1e3;
                let mut acc = StreamingEval::new();
                let batches =
                    StreamBatcher::new(stream, entry.fields, b, Tail::Pad);
                for item in batches.take(max_batches) {
                    let batch = item?;
                    let logits = serve_batch(&batch);
                    acc.push(&logits, &batch.labels, batch.valid);
                }
                (
                    acc.auc(),
                    acc.logloss(),
                    acc.len(),
                    data_ms,
                    source.warnings(),
                )
            }
        };
    if latencies.is_empty() {
        bail!("no test batches to serve (max_batches or split too small)");
    }

    Ok(ServeReport {
        method: store.method_name(),
        n_features: store.n_features(),
        dim: store.dim(),
        infer_bytes: store.infer_bytes(),
        fp_bytes: fp_bytes(store.n_features(), store.dim()),
        batch_size: b,
        requests,
        auc,
        logloss,
        latencies_ms: latencies,
        load_ms,
        data_ms,
        warnings,
        exp,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::save_store;
    use crate::config::Method;
    use crate::coordinator::Trainer;
    use crate::data::Schema;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("alpt_serve_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn tiny_trained_ckpt(name: &str) -> std::path::PathBuf {
        let exp = Experiment {
            method: Method::Lpt(crate::config::RoundingMode::Sr),
            model: "tiny".into(),
            dataset: "tiny".into(),
            n_samples: 2000,
            use_runtime: false,
            threads: 1,
            ..Experiment::default()
        };
        let spec = SyntheticSpec::tiny(exp.seed);
        let n = Schema::new(spec.vocabs.clone()).n_features();
        let tr = Trainer::new(exp, n).unwrap();
        let path = tmp(name);
        tr.save_checkpoint(&path).unwrap();
        path
    }

    #[test]
    fn serves_from_a_trainer_checkpoint() {
        let path = tiny_trained_ckpt("serve_ok.ckpt");
        let report = serve_checkpoint(&path, 4).unwrap();
        assert_eq!(report.method, "LPT(SR)");
        assert_eq!(report.batches(), 4);
        // requests counts un-padded samples only
        assert!(
            report.requests > 0
                && report.requests <= 4 * report.batch_size,
            "requests={}",
            report.requests
        );
        assert!(report.auc.is_finite() && report.logloss.is_finite());
        assert!(report.infer_bytes < report.fp_bytes);
        assert!(report.requests_per_sec() > 0.0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn zero_batches_is_an_error_not_a_panic() {
        let path = tiny_trained_ckpt("serve_zero.ckpt");
        let err = format!("{:#}", serve_checkpoint(&path, 0).unwrap_err());
        assert!(err.contains("no test batches"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn serves_a_mixed_precision_checkpoint() {
        // a per-field plan builds a grouped store whose v2 checkpoint
        // must load and serve through the identical path
        let exp = Experiment {
            method: Method::Alpt(crate::config::RoundingMode::Sr),
            bits: crate::config::PrecisionPlan::parse(
                "f0:4,f1:8,default:2",
            )
            .unwrap(),
            model: "tiny".into(),
            dataset: "synthetic:tiny".into(),
            n_samples: 2000,
            use_runtime: false,
            threads: 1,
            ..Experiment::default()
        };
        let n = crate::data::registry::schema_for(&exp)
            .unwrap()
            .n_features();
        let tr = Trainer::new(exp, n).unwrap();
        let path = tmp("serve_mixed.ckpt");
        tr.save_checkpoint(&path).unwrap();
        let report = serve_checkpoint(&path, 4).unwrap();
        assert_eq!(report.method, "ALPT(SR)[mixed]");
        assert_eq!(report.n_features, n);
        assert!(report.auc.is_finite() && report.logloss.is_finite());
        assert!(
            report.infer_bytes < report.fp_bytes,
            "mixed table must still compress: {} vs {}",
            report.infer_bytes,
            report.fp_bytes
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn store_only_checkpoint_without_dense_is_rejected() {
        let exp = Experiment {
            method: Method::Fp,
            use_runtime: false,
            ..Experiment::default()
        };
        let mut rng = crate::util::rng::Pcg32::seeded(3);
        let store =
            crate::embedding::build_store(&exp, 40, 8, &mut rng).unwrap();
        let path = tmp("no_dense.ckpt");
        save_store(&path, store.as_ref(), &exp).unwrap();
        let err = format!("{:#}", serve_checkpoint(&path, 1).unwrap_err());
        assert!(err.contains("dense"), "{err}");
        std::fs::remove_file(&path).ok();
    }
}
