//! The L3 coordinator: training loop, evaluation, epoch scheduling, and
//! distributed parameter-server training over the wire.
//!
//! The [`Trainer`] owns everything stateful — the embedding store, the
//! dense parameters + Adam state, the PJRT runtime (or the pure-Rust nn
//! fallback), the PRNG streams — and drives the per-batch protocol:
//!
//! ```text
//!   batcher ─▶ dedup ─▶ gather(store) ─▶ PJRT train artifact ─▶ grads
//!                                            │
//!              requantize ◀─ store.update ◀──┘   (+ ALPT second pass
//!                                                  through train_fq)
//! ```
//!
//! With `--workers N` the gather/update arrows cross process
//! boundaries: [`sharding::RowPartition`] splits row ids across worker
//! processes, [`net`] frames the CRC-checked GATHER/UPDATE RPC, and
//! [`worker::run_worker`] is the `alpt worker` serve loop. The
//! coordinator keeps the dense model and the data stream; workers keep
//! the packed rows. Results are bit-identical to single-process at any
//! worker count.

pub mod net;
pub mod serve;
pub mod sharding;
pub mod trainer;
pub mod worker;

pub use net::{RpcConfig, WorkerHub};
pub use serve::{
    sample_requests, serve_checkpoint, serve_with_engine, SampleRequest,
    ServeReport,
};
pub use sharding::{CommStats, RowPartition};
pub use trainer::{
    builtin_entry, EarlyStop, EpochReport, EvalReport, TrainResult, Trainer,
};
pub use worker::{run_worker, WorkerOpts};
