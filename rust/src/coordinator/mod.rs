//! The L3 coordinator: training loop, evaluation, epoch scheduling, and
//! the sharded leader/worker communication simulation.
//!
//! The [`Trainer`] owns everything stateful — the embedding store, the
//! dense parameters + Adam state, the PJRT runtime (or the pure-Rust nn
//! fallback), the PRNG streams — and drives the per-batch protocol:
//!
//! ```text
//!   batcher ─▶ dedup ─▶ gather(store) ─▶ PJRT train artifact ─▶ grads
//!                                            │
//!              requantize ◀─ store.update ◀──┘   (+ ALPT second pass
//!                                                  through train_fq)
//! ```

pub mod serve;
pub mod sharding;
pub mod trainer;

pub use serve::{
    sample_requests, serve_checkpoint, serve_with_engine, SampleRequest,
    ServeReport,
};
pub use sharding::{CommStats, ShardedStore};
pub use trainer::{
    builtin_entry, EarlyStop, EpochReport, EvalReport, TrainResult, Trainer,
};
