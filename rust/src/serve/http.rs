//! Std-only HTTP/1.1 scoring server (`alpt serve --listen`).
//!
//! No web framework — the repo is offline-vendored, so the server is a
//! `TcpListener`, a small worker-thread pool, and a hand-rolled
//! HTTP/1.1 request parser. Endpoints:
//!
//! * `POST /score`  — JSON feature-index records → logits/probabilities
//!   (micro-batched through [`crate::serve::batch::MicroBatcher`]);
//! * `GET  /healthz` — liveness + the live model's identity;
//! * `GET  /stats`  — request counters and p50/p95/p99 latency from a
//!   [`LatencyHistogram`];
//! * `POST /reload` — atomic checkpoint hot-swap (see [`EngineHandle`]);
//! * `POST /shutdown` — stop accepting, drain, and return from `run`.
//!
//! Wire protocol (see README.md "Online serving"): a score request body
//! is `{"records": [[id, …], …]}` (or a bare array of records), each
//! record exactly `fields` global feature ids; the response is
//! `{"logits": [...], "probs": [...]}` in request order. Malformed
//! bodies get HTTP 400 and the worker lives on.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::checkpoint::journal_path;
use crate::metrics::{sigmoid, LatencyHistogram};
use crate::serve::batch::MicroBatcher;
use crate::serve::engine::InferenceEngine;
use crate::util::json::Json;

/// The hot-swap slot: the live engine sits behind `Mutex<Arc<_>>`, and
/// readers only ever hold the lock for the `Arc` clone (a pointer copy +
/// refcount bump — never during scoring), so a swap waits on no reader
/// and a reader waits on no swap-in-progress load. In-flight requests
/// keep their cloned `Arc` and finish on the model they started with;
/// the old engine is freed when its last in-flight request drops it.
pub struct EngineHandle {
    slot: Mutex<Arc<InferenceEngine>>,
    reloads: AtomicU64,
    reload_failures: AtomicU64,
}

impl EngineHandle {
    pub fn new(engine: InferenceEngine) -> Self {
        Self {
            slot: Mutex::new(Arc::new(engine)),
            reloads: AtomicU64::new(0),
            reload_failures: AtomicU64::new(0),
        }
    }

    /// The live engine (O(1): pointer clone, no scoring under the lock).
    pub fn current(&self) -> Arc<InferenceEngine> {
        Arc::clone(&self.slot.lock().unwrap())
    }

    /// Atomically publish `engine`; returns the replaced one.
    pub fn swap(&self, engine: InferenceEngine) -> Arc<InferenceEngine> {
        let mut slot = self.slot.lock().unwrap();
        let old = std::mem::replace(&mut *slot, Arc::new(engine));
        self.reloads.fetch_add(1, Ordering::Relaxed);
        old
    }

    pub fn reloads(&self) -> u64 {
        self.reloads.load(Ordering::Relaxed)
    }

    /// Reload attempts that failed validation and kept the old engine.
    pub fn reload_failures(&self) -> u64 {
        self.reload_failures.load(Ordering::Relaxed)
    }

    /// Load `path` and swap it in — shared by `/reload` and `--watch`.
    /// The new checkpoint may use any store family / precision plan /
    /// checkpoint format version, but must keep the wire protocol: the
    /// field count cannot change under live clients. On any failure the
    /// live engine stays published and the failure counter ticks up.
    pub fn reload_from(&self, path: &std::path::Path) -> Result<()> {
        match self.try_reload(path) {
            Ok(()) => Ok(()),
            Err(e) => {
                self.reload_failures.fetch_add(1, Ordering::Relaxed);
                Err(e)
            }
        }
    }

    fn try_reload(&self, path: &std::path::Path) -> Result<()> {
        let fresh = InferenceEngine::from_checkpoint(path)
            .with_context(|| format!("reloading {}", path.display()))?;
        let live_fields = self.current().fields();
        if fresh.fields() != live_fields {
            bail!(
                "checkpoint model has {} fields, the live server speaks \
                 {live_fields}-field records",
                fresh.fields()
            );
        }
        self.swap(fresh);
        Ok(())
    }
}

/// Server configuration (`alpt serve --listen …`).
pub struct ServerConfig {
    /// Bind address, e.g. `127.0.0.1:8080` (`:0` picks a free port).
    pub listen: String,
    /// Checkpoint to serve (and the default `/reload` target).
    pub ckpt: PathBuf,
    /// Connection-handler threads.
    pub workers: usize,
    /// Micro-batch coalescing budget after the first queued record.
    pub max_wait: Duration,
    /// Bound on queued (unscored) records; beyond it `/score` gets 503.
    pub queue_cap: usize,
    /// Poll the checkpoint file and hot-swap on mtime change (`None`
    /// disables watching).
    pub watch: Option<Duration>,
}

impl ServerConfig {
    pub fn new(listen: &str, ckpt: &std::path::Path) -> Self {
        Self {
            listen: listen.to_string(),
            ckpt: ckpt.to_path_buf(),
            workers: 4,
            max_wait: Duration::from_millis(2),
            queue_cap: 4096,
            watch: None,
        }
    }
}

/// Request counters shared across workers (all lock-free).
struct Stats {
    requests: AtomicU64,
    errors: AtomicU64,
    latency: LatencyHistogram,
    started: Instant,
}

/// Flips its flag to false when dropped — including on unwind, so a
/// panicking scorer thread is detected by `/healthz` instead of leaving
/// a server that looks healthy while every `/score` fails.
struct AliveGuard(Arc<AtomicBool>);

impl Drop for AliveGuard {
    fn drop(&mut self) {
        self.0.store(false, Ordering::SeqCst);
    }
}

/// A bound scoring server. `bind` loads the checkpoint and claims the
/// port (so callers can read [`Server::local_addr`] before serving);
/// [`Server::run`] blocks until `POST /shutdown`.
pub struct Server {
    cfg: ServerConfig,
    listener: TcpListener,
    handle: Arc<EngineHandle>,
    stats: Arc<Stats>,
    stop: Arc<AtomicBool>,
    /// Checkpoint and delta-journal mtimes captured *before* the engine
    /// load, so a file rewritten during (or right after) the load still
    /// triggers the first `--watch` reload instead of silently becoming
    /// the baseline.
    watch_baseline: (
        Option<std::time::SystemTime>,
        Option<std::time::SystemTime>,
    ),
}

impl Server {
    pub fn bind(cfg: ServerConfig) -> Result<Server> {
        let mtime_of = |p: &std::path::Path| {
            std::fs::metadata(p).and_then(|m| m.modified()).ok()
        };
        let watch_baseline =
            (mtime_of(&cfg.ckpt), mtime_of(&journal_path(&cfg.ckpt)));
        let engine = InferenceEngine::from_checkpoint(&cfg.ckpt)?;
        let listener = TcpListener::bind(&cfg.listen)
            .with_context(|| format!("binding {}", cfg.listen))?;
        Ok(Server {
            cfg,
            listener,
            handle: Arc::new(EngineHandle::new(engine)),
            stats: Arc::new(Stats {
                requests: AtomicU64::new(0),
                errors: AtomicU64::new(0),
                latency: LatencyHistogram::new(),
                started: Instant::now(),
            }),
            stop: Arc::new(AtomicBool::new(false)),
            watch_baseline,
        })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// The live-engine handle (tests swap through it directly).
    pub fn engine_handle(&self) -> Arc<EngineHandle> {
        Arc::clone(&self.handle)
    }

    /// Accept-and-serve until `POST /shutdown`. Spawns the scorer, the
    /// optional checkpoint watcher, and `workers` connection handlers;
    /// joins them all before returning, so a clean return means every
    /// queued record was scored or answered.
    pub fn run(self) -> Result<()> {
        let (mb, scorer) =
            MicroBatcher::new(self.cfg.queue_cap, self.cfg.max_wait);
        let scorer_alive = Arc::new(AtomicBool::new(true));
        let scorer_handle = {
            let h = Arc::clone(&self.handle);
            let guard = AliveGuard(Arc::clone(&scorer_alive));
            std::thread::spawn(move || {
                let _guard = guard;
                scorer.run(move || h.current())
            })
        };
        let watcher_handle = self.cfg.watch.map(|period| {
            let h = Arc::clone(&self.handle);
            let stop = Arc::clone(&self.stop);
            let path = self.cfg.ckpt.clone();
            let baseline = self.watch_baseline;
            std::thread::spawn(move || {
                watch_loop(&h, &stop, &path, period, baseline)
            })
        });

        // bounded dispatch: when every worker is busy and the backlog
        // is full, shed the connection instead of queueing fds without
        // bound (a flood would otherwise exhaust descriptors)
        let (tx, rx) = mpsc::sync_channel::<TcpStream>(
            self.cfg.workers.max(1) * 4,
        );
        let rx = Arc::new(Mutex::new(rx));
        let workers: Vec<_> = (0..self.cfg.workers.max(1))
            .map(|_| {
                let rx = Arc::clone(&rx);
                let ctx = Ctx {
                    handle: Arc::clone(&self.handle),
                    stats: Arc::clone(&self.stats),
                    stop: Arc::clone(&self.stop),
                    scorer_alive: Arc::clone(&scorer_alive),
                    mb: mb.clone(),
                    ckpt: self.cfg.ckpt.clone(),
                };
                std::thread::spawn(move || loop {
                    let stream = match rx.lock().unwrap().recv() {
                        Ok(s) => s,
                        Err(_) => return,
                    };
                    // per-connection failures must never kill a worker
                    let _ = handle_connection(stream, &ctx);
                })
            })
            .collect();

        // poll-based accept: shutdown must not depend on one more
        // connection arriving (or on a best-effort loopback nudge), and
        // accept errors (EMFILE under flood) must back off, not spin
        self.listener.set_nonblocking(true)?;
        while !self.stop.load(Ordering::SeqCst) {
            match self.listener.accept() {
                Ok((s, _)) => {
                    // workers do blocking reads with timeouts
                    if s.set_nonblocking(false).is_err() {
                        continue;
                    }
                    // full backlog: drop the connection (load shedding)
                    let _ = tx.try_send(s);
                }
                // WouldBlock (no connection waiting) and real accept
                // errors (EMFILE under flood) both back off one tick
                Err(_) => std::thread::sleep(Duration::from_millis(10)),
            }
        }
        // drain: close the dispatch channel, let workers finish their
        // current connection, then retire the scorer
        drop(tx);
        for w in workers {
            let _ = w.join();
        }
        mb.close();
        let _ = scorer_handle.join();
        if let Some(w) = watcher_handle {
            let _ = w.join();
        }
        Ok(())
    }
}

/// `--watch`: poll the checkpoint's mtime — and its delta journal's, so
/// continuous-training runs that only append deltas between full
/// anchors still get picked up — and on change, reload + swap. `last`
/// is the baseline captured at bind time, before the engine load — not
/// re-read here, so no write window is ever missed.
///
/// A failed reload keeps the live engine and is retried with capped
/// exponential backoff (period × 2^failures, capped at 64×): a
/// persistently corrupt file is logged and counted in `/stats`
/// (`reload_failures`) without hammering the disk every period, and the
/// first good rewrite after a failure streak swaps in as soon as the
/// backed-off poll fires.
fn watch_loop(
    handle: &EngineHandle,
    stop: &AtomicBool,
    path: &std::path::Path,
    period: Duration,
    mut last: (
        Option<std::time::SystemTime>,
        Option<std::time::SystemTime>,
    ),
) {
    let journal = journal_path(path);
    let mtime_of = |p: &std::path::Path| {
        std::fs::metadata(p).and_then(|m| m.modified()).ok()
    };
    // sleep in short ticks (stop-flag responsiveness) but only poll the
    // mtimes once per configured period — a long --watch-ms is a
    // debounce for slow checkpoint writers, not a suggestion
    let tick = period.min(Duration::from_millis(200)).max(
        Duration::from_millis(10),
    );
    let mut since_poll = Duration::ZERO;
    let mut failures = 0u32;
    while !stop.load(Ordering::SeqCst) {
        std::thread::sleep(tick);
        since_poll += tick;
        let wait = period.saturating_mul(1 << failures.min(6));
        if since_poll < wait {
            continue;
        }
        since_poll = Duration::ZERO;
        let now = (mtime_of(path), mtime_of(&journal));
        if now.0.is_some() && now != last {
            match handle.reload_from(path) {
                Ok(()) => {
                    last = now;
                    failures = 0;
                    eprintln!(
                        "[watch] reloaded {} ({}, {} deltas folded)",
                        path.display(),
                        handle.current().method_name(),
                        handle.current().deltas_folded()
                    );
                }
                // a half-written file fails validation; the live engine
                // keeps serving and the retry backs off
                Err(e) => {
                    failures = failures.saturating_add(1);
                    eprintln!(
                        "[watch] reload failed (retry in {:.1}s): {e:#}",
                        period
                            .saturating_mul(1 << failures.min(6))
                            .as_secs_f64()
                    );
                }
            }
        }
    }
}

/// Per-worker context.
struct Ctx {
    handle: Arc<EngineHandle>,
    stats: Arc<Stats>,
    stop: Arc<AtomicBool>,
    /// False once the scorer thread has exited (panic included) — flips
    /// `/healthz` to 503 so orchestrators stop routing traffic here.
    scorer_alive: Arc<AtomicBool>,
    mb: MicroBatcher,
    ckpt: PathBuf,
}

const MAX_HEAD_BYTES: usize = 16 * 1024;
const MAX_BODY_BYTES: usize = 4 * 1024 * 1024;

struct Request {
    method: String,
    path: String,
    body: Vec<u8>,
    keep_alive: bool,
}

/// Serve requests off one connection until EOF, error, or shutdown.
fn handle_connection(mut stream: TcpStream, ctx: &Ctx) -> Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(10)))?;
    // a client that stops reading must not wedge a worker in write_all
    // forever (enough of those would starve even POST /shutdown)
    stream.set_write_timeout(Some(Duration::from_secs(30)))?;
    stream.set_nodelay(true).ok();
    let mut pending = Vec::new();
    loop {
        let req = match read_request(&mut stream, &mut pending) {
            Ok(Some(r)) => r,
            // clean EOF between requests: client is done
            Ok(None) => return Ok(()),
            Err(e) => {
                // syntactically broken request: answer 400 and drop the
                // connection (framing is unrecoverable), worker survives
                let _ = respond_json(
                    &mut stream,
                    400,
                    "Bad Request",
                    &err_json(&format!("{e:#}")),
                    false,
                );
                return Ok(());
            }
        };
        let keep = req.keep_alive;
        route(&mut stream, ctx, req)?;
        if !keep || ctx.stop.load(Ordering::SeqCst) {
            return Ok(());
        }
    }
}

fn route(stream: &mut TcpStream, ctx: &Ctx, req: Request) -> Result<()> {
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/score") => {
            ctx.stats.requests.fetch_add(1, Ordering::Relaxed);
            let t = Instant::now();
            match score_body(ctx, &req.body) {
                Ok(json) => {
                    ctx.stats
                        .latency
                        .record_ms(t.elapsed().as_secs_f64() * 1e3);
                    respond_json(stream, 200, "OK", &json, req.keep_alive)
                }
                Err(fail) => {
                    ctx.stats.errors.fetch_add(1, Ordering::Relaxed);
                    let (code, reason, msg) = fail.status();
                    respond_json(
                        stream,
                        code,
                        reason,
                        &err_json(&msg),
                        req.keep_alive,
                    )
                }
            }
        }
        ("GET", "/healthz") => {
            let engine = ctx.handle.current();
            // a dead scorer means every /score fails: report unhealthy
            // so load balancers stop routing here, instead of a 200
            // façade over a server that 503s all traffic
            let alive = ctx.scorer_alive.load(Ordering::SeqCst);
            let (code, reason, status) = if alive {
                (200, "OK", "ok")
            } else {
                (503, "Service Unavailable", "unhealthy: scorer exited")
            };
            let json = Json::obj(vec![
                ("method", Json::str(engine.method_name())),
                ("model", Json::str(&engine.exp().model)),
                ("n_features", Json::num(engine.n_features() as f64)),
                ("status", Json::str(status)),
            ]);
            respond_json(stream, code, reason, &json, req.keep_alive)
        }
        ("GET", "/stats") => {
            let engine = ctx.handle.current();
            let lat = &ctx.stats.latency;
            let json = Json::obj(vec![
                (
                    "errors",
                    Json::num(
                        ctx.stats.errors.load(Ordering::Relaxed) as f64
                    ),
                ),
                ("kernel", Json::str(engine.kernel_name())),
                ("method", Json::str(engine.method_name())),
                ("p50_ms", Json::num(lat.percentile_ms(50.0))),
                ("p95_ms", Json::num(lat.percentile_ms(95.0))),
                ("p99_ms", Json::num(lat.percentile_ms(99.0))),
                ("batches_scored", Json::num(ctx.mb.batches_scored() as f64)),
                ("records_scored", Json::num(ctx.mb.records_scored() as f64)),
                (
                    "reload_failures",
                    Json::num(ctx.handle.reload_failures() as f64),
                ),
                ("reloads", Json::num(ctx.handle.reloads() as f64)),
                (
                    "requests",
                    Json::num(
                        ctx.stats.requests.load(Ordering::Relaxed) as f64
                    ),
                ),
                (
                    "uptime_s",
                    Json::num(ctx.stats.started.elapsed().as_secs_f64()),
                ),
            ]);
            respond_json(stream, 200, "OK", &json, req.keep_alive)
        }
        ("POST", "/reload") => {
            let path = reload_path(&req.body, &ctx.ckpt);
            match path.and_then(|p| {
                ctx.handle.reload_from(&p)?;
                Ok(p)
            }) {
                Ok(p) => {
                    let engine = ctx.handle.current();
                    let json = Json::obj(vec![
                        ("ckpt", Json::str(&p.display().to_string())),
                        ("method", Json::str(engine.method_name())),
                        ("reloaded", Json::Bool(true)),
                        (
                            "reloads",
                            Json::num(ctx.handle.reloads() as f64),
                        ),
                    ]);
                    respond_json(stream, 200, "OK", &json, req.keep_alive)
                }
                Err(e) => {
                    ctx.stats.errors.fetch_add(1, Ordering::Relaxed);
                    respond_json(
                        stream,
                        409,
                        "Conflict",
                        &err_json(&format!("{e:#}")),
                        req.keep_alive,
                    )
                }
            }
        }
        ("POST", "/shutdown") => {
            // the poll-based accept loop notices the flag within one
            // poll tick — no wake-up connection needed
            ctx.stop.store(true, Ordering::SeqCst);
            respond_json(
                stream,
                200,
                "OK",
                &Json::obj(vec![("ok", Json::Bool(true))]),
                false,
            )
        }
        (_, path) => respond_json(
            stream,
            404,
            "Not Found",
            &err_json(&format!("no route {path:?}")),
            req.keep_alive,
        ),
    }
}

/// Why a `/score` request failed, typed so the HTTP status reflects the
/// actual condition: client mistakes get 400, server overload/shutdown
/// 503 (retryable), a scorer that exists but cannot keep up 504.
enum ScoreFailure {
    BadRequest(String),
    Unavailable(String),
    Timeout(String),
}

impl ScoreFailure {
    fn status(self) -> (u16, &'static str, String) {
        match self {
            ScoreFailure::BadRequest(m) => (400, "Bad Request", m),
            ScoreFailure::Unavailable(m) => {
                (503, "Service Unavailable", m)
            }
            ScoreFailure::Timeout(m) => (504, "Gateway Timeout", m),
        }
    }
}

/// Parse + score a `/score` body through the micro-batch queue.
fn score_body(ctx: &Ctx, body: &[u8]) -> Result<Json, ScoreFailure> {
    let bad = ScoreFailure::BadRequest;
    let text = std::str::from_utf8(body)
        .map_err(|_| bad("body is not UTF-8".into()))?;
    let json = Json::parse(text)
        .map_err(|e| bad(format!("body is not valid JSON: {e:#}")))?;
    let records = match &json {
        Json::Array(v) => v.as_slice(),
        Json::Object(_) => json
            .opt("records")
            .ok_or_else(|| bad("body object has no \"records\" key".into()))?
            .as_array()
            .map_err(|_| bad("\"records\" is not an array".into()))?,
        _ => {
            return Err(bad(
                "body must be a records array or {\"records\": …}".into(),
            ))
        }
    };
    if records.is_empty() {
        return Err(bad("no records to score".into()));
    }
    // a request that exceeds the queue capacity can never be accepted —
    // that's a client error (400), not retryable overload (503)
    if records.len() > ctx.mb.capacity() {
        return Err(bad(format!(
            "request holds {} records, the scoring queue capacity is {}",
            records.len(),
            ctx.mb.capacity()
        )));
    }
    let engine = ctx.handle.current();
    let fields = engine.fields();
    let limit = engine.n_features();
    let mut features = Vec::with_capacity(records.len());
    for (i, rec) in records.iter().enumerate() {
        let ids = rec
            .as_array()
            .map_err(|_| bad(format!("record {i} is not an array")))?;
        if ids.len() != fields {
            return Err(bad(format!(
                "record {i} holds {} ids, model expects {fields}",
                ids.len()
            )));
        }
        let mut rec_ids = Vec::with_capacity(fields);
        for v in ids {
            let id = v.as_usize().map_err(|_| {
                bad(format!("record {i}: bad feature id"))
            })?;
            // full validation before anything queues: one bad record
            // fails the request fast with 400 instead of wasting
            // forward-pass work on its siblings
            if id >= limit {
                return Err(bad(format!(
                    "record {i}: feature id {id} out of range (table \
                     holds {limit} rows)"
                )));
            }
            rec_ids.push(id as u32);
        }
        features.push(rec_ids);
    }
    // all-or-nothing: a rejected request leaves nothing queued behind;
    // the engine the records were validated against travels with them,
    // so a hot swap mid-queue cannot invalidate an accepted request
    let receivers = ctx
        .mb
        .submit_many(Arc::clone(&engine), features)
        .map_err(|e| ScoreFailure::Unavailable(e.to_string()))?;
    let mut logits = Vec::with_capacity(receivers.len());
    // one deadline for the whole request, not per record — N records
    // must not stretch the documented 30 s budget to N × 30 s
    let deadline = Instant::now() + Duration::from_secs(30);
    for (i, rx) in receivers.into_iter().enumerate() {
        let left = deadline.saturating_duration_since(Instant::now());
        match rx.recv_timeout(left) {
            Ok(Ok(z)) => logits.push(z as f64),
            Ok(Err(msg)) => return Err(bad(format!("record {i}: {msg}"))),
            Err(mpsc::RecvTimeoutError::Timeout) => {
                return Err(ScoreFailure::Timeout(format!(
                    "record {i}: scoring timed out"
                )))
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                return Err(ScoreFailure::Unavailable(format!(
                    "record {i}: scorer shut down before replying"
                )))
            }
        }
    }
    let probs: Vec<f64> =
        logits.iter().map(|&z| sigmoid(z as f32) as f64).collect();
    Ok(Json::obj(vec![
        ("logits", Json::arr_f64(&logits)),
        ("probs", Json::arr_f64(&probs)),
    ]))
}

/// `/reload` body: empty → the server's own checkpoint path; otherwise
/// `{"ckpt": "path"}`.
fn reload_path(body: &[u8], default: &std::path::Path) -> Result<PathBuf> {
    let text = std::str::from_utf8(body).unwrap_or("").trim();
    if text.is_empty() {
        return Ok(default.to_path_buf());
    }
    let json = Json::parse(text).context("reload body is not JSON")?;
    match json.opt("ckpt") {
        Some(v) => Ok(PathBuf::from(v.as_str()?)),
        None => Ok(default.to_path_buf()),
    }
}

fn err_json(msg: &str) -> Json {
    Json::obj(vec![("error", Json::str(msg))])
}

/// Read one HTTP/1.1 request. `Ok(None)` on clean EOF before any bytes
/// of a new request (keep-alive connection closed by the client).
fn read_request(
    stream: &mut TcpStream,
    buf: &mut Vec<u8>,
) -> Result<Option<Request>> {
    let mut chunk = [0u8; 4096];
    let head_end = loop {
        if let Some(pos) = find_head_end(buf) {
            break pos;
        }
        if buf.len() > MAX_HEAD_BYTES {
            bail!("request head exceeds {MAX_HEAD_BYTES} bytes");
        }
        let n = match stream.read(&mut chunk) {
            Ok(n) => n,
            // an idle keep-alive connection hitting the read timeout is
            // not a malformed request: close silently, never answer 400
            // to a client that hasn't sent anything
            Err(e)
                if buf.is_empty()
                    && matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock
                            | std::io::ErrorKind::TimedOut
                    ) =>
            {
                return Ok(None)
            }
            Err(e) => return Err(e).context("reading request"),
        };
        if n == 0 {
            if buf.is_empty() {
                return Ok(None);
            }
            bail!("connection closed mid-request");
        }
        buf.extend_from_slice(&chunk[..n]);
    };

    let head = std::str::from_utf8(&buf[..head_end])
        .context("request head is not UTF-8")?;
    let mut lines = head.split("\r\n");
    let request_line =
        lines.next().ok_or_else(|| anyhow!("empty request"))?;
    let mut parts = request_line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| anyhow!("missing method"))?
        .to_uppercase();
    let path = parts
        .next()
        .ok_or_else(|| anyhow!("missing path"))?
        .to_string();
    let version = parts.next().unwrap_or("HTTP/1.0");

    let mut content_length = 0usize;
    // HTTP/1.1 defaults to keep-alive, 1.0 to close
    let mut keep_alive = version.ends_with("1.1");
    let mut expect_continue = false;
    for line in lines {
        let Some((k, v)) = line.split_once(':') else { continue };
        let (k, v) = (k.trim().to_ascii_lowercase(), v.trim());
        if k == "content-length" {
            content_length = v
                .parse::<usize>()
                .map_err(|_| anyhow!("bad Content-Length {v:?}"))?;
        } else if k == "connection" {
            keep_alive = !v.eq_ignore_ascii_case("close");
        } else if k == "transfer-encoding" {
            // we only frame bodies by Content-Length; silently treating
            // a chunked body as empty would desync the connection
            bail!(
                "Transfer-Encoding {v:?} is not supported; send a \
                 Content-Length body"
            );
        } else if k == "expect"
            && v.eq_ignore_ascii_case("100-continue")
        {
            // curl sends this for bodies over ~1 KiB and stalls ~1 s
            // waiting for the interim response
            expect_continue = true;
        }
    }
    if content_length > MAX_BODY_BYTES {
        bail!("body of {content_length} bytes exceeds {MAX_BODY_BYTES}");
    }
    if expect_continue {
        stream.write_all(b"HTTP/1.1 100 Continue\r\n\r\n")?;
    }

    let body_start = head_end + 4;
    while buf.len() < body_start + content_length {
        let n = stream.read(&mut chunk).context("reading body")?;
        if n == 0 {
            bail!("connection closed mid-body");
        }
        buf.extend_from_slice(&chunk[..n]);
    }
    let body = buf[body_start..body_start + content_length].to_vec();
    // keep any pipelined bytes for the next request on this connection
    buf.drain(..body_start + content_length);
    Ok(Some(Request { method, path, body, keep_alive }))
}

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

fn respond_json(
    stream: &mut TcpStream,
    code: u16,
    reason: &str,
    body: &Json,
    keep_alive: bool,
) -> Result<()> {
    let payload = body.to_string();
    let head = format!(
        "HTTP/1.1 {code} {reason}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: {}\r\n\r\n",
        payload.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(payload.as_bytes())?;
    stream.flush()?;
    Ok(())
}
