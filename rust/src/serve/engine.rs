//! The shared online-inference engine.
//!
//! [`InferenceEngine`] is an **immutable**, `Send + Sync` bundle of
//! everything one model needs to score requests: the restored
//! [`EmbeddingStore`] (fp / lpt / alpt / hashing / pruning / grouped
//! mixed-precision, including hashed+pruned structural groups), the
//! DCN dense parameters, and the model geometry. Scoring takes `&self`
//! and per-thread scratch, so any number of threads can score against
//! one shared engine concurrently — and, because gather and the Rust
//! DCN forward are pure functions of the batch, every thread's logits
//! are bit-identical to the serial path (property-tested in
//! `rust/tests/serve_online.rs`).
//!
//! The single inference body lives in [`score_batch`]; the offline
//! batch-eval loop (`coordinator::serve_checkpoint`), the trainer's
//! non-runtime eval path (`Trainer::batch_logits`), the HTTP scoring
//! server (`serve::http`) and `examples/serve.rs` all route through it,
//! so the entry points cannot drift apart.

use std::path::Path;
use std::time::Instant;

use anyhow::{ensure, Result};

use crate::checkpoint::{dense_params, journal, load_store, Checkpoint};
use crate::config::Experiment;
use crate::coordinator::builtin_entry;
use crate::data::batcher::{build_batch, Batch};
use crate::embedding::{fp_bytes, EmbeddingStore};
use crate::nn::Dcn;
use crate::runtime::ModelEntry;

/// The one shared gather → DCN-forward body. `emb` is caller scratch of
/// at least `umax * store.dim()` floats: rows beyond the batch's uniques
/// are zeroed so the shape-static forward always sees a full `[umax, d]`
/// table. Pure in `(store contents, dense, batch)` — the same batch
/// scores to the same bits on any thread.
pub fn score_batch(
    store: &dyn EmbeddingStore,
    dcn: &Dcn,
    dense: &[f32],
    umax: usize,
    batch: &Batch,
    emb: &mut [f32],
) -> Vec<f32> {
    let d = store.dim();
    let n_u = batch.unique.len();
    debug_assert!(n_u <= umax, "batch uniques exceed umax");
    emb[n_u * d..umax * d].fill(0.0);
    store.gather(&batch.unique, &mut emb[..n_u * d]);
    dcn.infer(&emb[..umax * d], &batch.idx, dense)
}

/// Per-thread scoring scratch: the `[umax, d]` dequantized-row buffer
/// the forward pass reads. One per scoring thread — never shared.
pub struct ScoreScratch {
    emb: Vec<f32>,
}

impl ScoreScratch {
    /// Scratch sized for `engine` (umax × dim floats).
    pub fn for_engine(engine: &InferenceEngine) -> Self {
        Self { emb: vec![0.0; engine.entry.umax * engine.entry.emb_dim] }
    }
}

std::thread_local! {
    // fallback scratch for `score`: one buffer per OS thread, grown to
    // the largest engine that thread has scored with
    static TLS_SCRATCH: std::cell::RefCell<Vec<f32>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

/// An immutable, concurrency-safe inference bundle restored from a
/// checkpoint (or assembled from parts). See the module docs.
pub struct InferenceEngine {
    store: Box<dyn EmbeddingStore>,
    dense: Vec<f32>,
    dcn: Dcn,
    entry: ModelEntry,
    exp: Experiment,
    /// Checkpoint read + validation time in milliseconds (0 when built
    /// from parts).
    load_ms: f64,
    /// Delta-journal records folded on top of the anchor at load time
    /// (0 when built from parts or served from a bare checkpoint).
    deltas_folded: usize,
}

// the engine is shared across scoring threads behind an Arc; fail the
// build, not the first deploy, if a field ever stops being Sync
const _: () = {
    const fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<InferenceEngine>();
};

impl InferenceEngine {
    /// Restore an engine from a checkpoint file: store rows (uniform v1,
    /// grouped mixed-precision v2 and kinded/aux-only v3 alike), dense
    /// params, and the
    /// model geometry from the experiment echo — validated before any
    /// scoring can happen. A CRC-chained delta journal next to the file
    /// (continuous training: `--save-every`) is validated and folded on
    /// top, so serving picks up the state of the last published delta,
    /// not just the last full anchor.
    pub fn from_checkpoint(path: &Path) -> Result<Self> {
        let t0 = Instant::now();
        let ckpt = Checkpoint::read(path)?;
        let (mut store, exp) = load_store(&ckpt)?;
        let mut dense = dense_params(&ckpt)?;
        let anchor_step = ckpt.meta_usize("step")? as u64;
        let mut folded = 0usize;
        if let Some(chain) =
            journal::read_chain(path, ckpt.anchor_id(), anchor_step)?
        {
            for d in &chain.deltas {
                journal::apply_rows(store.as_mut(), d)?;
            }
            if let Some(last) = chain.deltas.last() {
                ensure!(
                    last.dense.len() == dense.len(),
                    "delta carries {} dense params, the anchor {}",
                    last.dense.len(),
                    dense.len()
                );
                dense = last.dense.clone();
            }
            folded = chain.deltas.len();
        }
        let mut engine = Self::from_parts(store, dense, exp)?;
        engine.load_ms = t0.elapsed().as_secs_f64() * 1e3;
        engine.deltas_folded = folded;
        Ok(engine)
    }

    /// Assemble an engine from already-restored parts, validating the
    /// store and dense-parameter geometry against the model entry.
    pub fn from_parts(
        store: Box<dyn EmbeddingStore>,
        dense: Vec<f32>,
        exp: Experiment,
    ) -> Result<Self> {
        let entry = builtin_entry(&exp.model)?;
        ensure!(
            dense.len() == entry.n_params,
            "checkpoint holds {} dense params, model {:?} expects {}",
            dense.len(),
            exp.model,
            entry.n_params
        );
        ensure!(
            store.dim() == entry.emb_dim,
            "checkpoint embedding dim {} does not match model {:?} \
             (dim {})",
            store.dim(),
            exp.model,
            entry.emb_dim
        );
        let dcn = Dcn::new(entry.dcn_config());
        Ok(Self {
            store,
            dense,
            dcn,
            entry,
            exp,
            load_ms: 0.0,
            deltas_folded: 0,
        })
    }

    /// Score one batch through caller-provided scratch (the allocation-
    /// controlled path: one [`ScoreScratch`] per scoring thread).
    pub fn score_with(
        &self,
        batch: &Batch,
        scratch: &mut ScoreScratch,
    ) -> Vec<f32> {
        let need = self.entry.umax * self.entry.emb_dim;
        if scratch.emb.len() < need {
            scratch.emb.resize(need, 0.0);
        }
        score_batch(
            self.store.as_ref(),
            &self.dcn,
            &self.dense,
            self.entry.umax,
            batch,
            &mut scratch.emb,
        )
    }

    /// Score one batch through this thread's thread-local scratch — the
    /// convenience path for callers that don't manage scratch buffers.
    pub fn score(&self, batch: &Batch) -> Vec<f32> {
        TLS_SCRATCH.with(|cell| {
            let mut emb = cell.borrow_mut();
            let need = self.entry.umax * self.entry.emb_dim;
            if emb.len() < need {
                emb.resize(need, 0.0);
            }
            score_batch(
                self.store.as_ref(),
                &self.dcn,
                &self.dense,
                self.entry.umax,
                batch,
                &mut emb,
            )
        })
    }

    /// Score up to `batch_size` raw feature-index records (`[n, fields]`
    /// row-major global ids) and return one logit per record. Validates
    /// shape and id bounds — this is the wire-facing entry point, so bad
    /// input must error, never panic. Per-record logits are independent
    /// of batch composition (the DCN forward is row-wise), so a record
    /// scores to the same bits alone, micro-batched, or in the offline
    /// eval loop.
    pub fn score_records(&self, features: &[u32]) -> Result<Vec<f32>> {
        let f = self.entry.fields;
        ensure!(
            !features.is_empty() && features.len() % f == 0,
            "request holds {} feature ids, expected a non-empty multiple \
             of {f} (model {:?})",
            features.len(),
            self.exp.model
        );
        let n = features.len() / f;
        ensure!(
            n <= self.entry.batch,
            "request holds {n} records, the engine batch is {}",
            self.entry.batch
        );
        let limit = self.store.n_features() as u32;
        for &id in features {
            ensure!(
                id < limit,
                "feature id {id} out of range (table holds {limit} rows)"
            );
        }
        let labels = vec![0u8; n];
        let batch = build_batch(features, &labels, f, self.entry.batch);
        let mut logits = self.score(&batch);
        logits.truncate(n);
        Ok(logits)
    }

    // ------------------------------------------------------- accessors

    pub fn method_name(&self) -> &'static str {
        self.store.method_name()
    }

    /// The SIMD kernel decoding packed rows under every score call
    /// (process-wide dispatch; see [`crate::quant::kernels`]).
    pub fn kernel_name(&self) -> &'static str {
        crate::quant::kernels::active().name()
    }

    pub fn n_features(&self) -> usize {
        self.store.n_features()
    }

    pub fn dim(&self) -> usize {
        self.store.dim()
    }

    /// Bytes to ship the restored table for inference.
    pub fn infer_bytes(&self) -> usize {
        self.store.infer_bytes()
    }

    /// The fp32 baseline for the same geometry.
    pub fn fp_bytes(&self) -> usize {
        fp_bytes(self.store.n_features(), self.store.dim())
    }

    /// The model's (shape-static) batch size — the micro-batching cap.
    pub fn batch_size(&self) -> usize {
        self.entry.batch
    }

    pub fn fields(&self) -> usize {
        self.entry.fields
    }

    pub fn entry(&self) -> &ModelEntry {
        &self.entry
    }

    pub fn exp(&self) -> &Experiment {
        &self.exp
    }

    pub fn store(&self) -> &dyn EmbeddingStore {
        self.store.as_ref()
    }

    pub fn load_ms(&self) -> f64 {
        self.load_ms
    }

    /// Delta-journal records folded on top of the anchor at load time.
    pub fn deltas_folded(&self) -> usize {
        self.deltas_folded
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Method, PrecisionPlan, RoundingMode};
    use crate::coordinator::Trainer;
    use crate::data::registry;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("alpt_engine_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn engine_for(bits: &str, name: &str) -> InferenceEngine {
        let exp = Experiment {
            method: Method::Lpt(RoundingMode::Sr),
            bits: PrecisionPlan::parse(bits).unwrap(),
            model: "tiny".into(),
            dataset: "synthetic:tiny".into(),
            n_samples: 1500,
            use_runtime: false,
            threads: 1,
            ..Experiment::default()
        };
        let n = registry::schema_for(&exp).unwrap().n_features();
        let mut tr = Trainer::new(exp, n).unwrap();
        let path = tmp(name);
        tr.save_checkpoint(&path).unwrap();
        let engine = InferenceEngine::from_checkpoint(&path).unwrap();
        std::fs::remove_file(&path).ok();
        engine
    }

    #[test]
    fn tls_and_explicit_scratch_agree() {
        let engine = engine_for("8", "scratch.ckpt");
        let features: Vec<u32> = (0..engine.fields() as u32).collect();
        let labels = [1u8];
        let batch = build_batch(
            &features,
            &labels,
            engine.fields(),
            engine.batch_size(),
        );
        let mut scratch = ScoreScratch::for_engine(&engine);
        let a = engine.score(&batch);
        let b = engine.score_with(&batch, &mut scratch);
        assert_eq!(a, b);
        assert_eq!(a.len(), engine.batch_size());
        assert!(a.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn structural_plan_checkpoint_scores_after_reload() {
        // hashed + pruned groups ride the v3 kinded format through
        // save → reload → serve
        let engine =
            engine_for("f0:hash,f1:prune,default:4", "structural.ckpt");
        let features: Vec<u32> = (0..engine.fields() as u32).collect();
        let labels = [0u8];
        let batch = build_batch(
            &features,
            &labels,
            engine.fields(),
            engine.batch_size(),
        );
        let logits = engine.score(&batch);
        assert!(logits.iter().all(|x| x.is_finite()));
        assert!(engine.infer_bytes() > 0);
    }

    #[test]
    fn score_records_validates_and_matches_batched() {
        let engine = engine_for("f0:4,default:8", "records.ckpt");
        let f = engine.fields();
        // three records over valid per-field ids
        let schema =
            registry::schema_for(engine.exp()).unwrap();
        let mut features = Vec::new();
        for r in 0..3u32 {
            for field in 0..f {
                features.push(schema.global_id(field, r % 2));
            }
        }
        let logits = engine.score_records(&features).unwrap();
        assert_eq!(logits.len(), 3);
        // single-record scoring is bit-identical: batch composition
        // must not change a record's logit
        for r in 0..3 {
            let solo = engine
                .score_records(&features[r * f..(r + 1) * f])
                .unwrap();
            assert_eq!(solo[0].to_bits(), logits[r].to_bits(), "r={r}");
        }
        // shape errors
        assert!(engine.score_records(&[]).is_err());
        assert!(engine.score_records(&features[..f - 1]).is_err());
        // id out of range
        let mut bad = features.clone();
        bad[0] = engine.n_features() as u32;
        assert!(engine.score_records(&bad).is_err());
        // too many records
        let huge = vec![0u32; (engine.batch_size() + 1) * f];
        assert!(engine.score_records(&huge).is_err());
    }
}
