//! Bounded micro-batching queue for the online scoring path.
//!
//! Many concurrent connections each carry one (or a few) records; the
//! engine's forward pass is shape-static at `batch_size` records — so
//! scoring each request alone wastes almost the whole batch. The
//! [`MicroBatcher`] coalesces: requests enqueue their records and block
//! on a per-request reply channel; a scorer thread drains up to
//! `max_batch` records per engine call, waiting at most `max_wait` after
//! the first record arrives so a lone request still sees bounded
//! latency. The queue is bounded (`queue_cap` records): a full queue
//! rejects at submit time (the HTTP layer maps that to 503) instead of
//! buffering unboundedly.
//!
//! Per-record logits are independent of batch composition (the DCN
//! forward is row-wise), so micro-batched scores are bit-identical to
//! scoring each record alone — tested below and in
//! `rust/tests/serve_online.rs`.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::data::batcher::build_batch;
use crate::serve::engine::{InferenceEngine, ScoreScratch};

/// Why a submit was rejected — typed so the HTTP layer can map
/// overload/shutdown to 503 without string-matching error text.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// The queue is at capacity (holds the queued-record count).
    Full(usize),
    /// The batcher is shutting down.
    Closed,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Full(n) => {
                write!(f, "scoring queue full ({n} records queued)")
            }
            SubmitError::Closed => write!(f, "scoring queue is shut down"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// One queued record: its feature ids, where to send the logit, and the
/// engine that accepted it. Snapshotting the engine at submit time is
/// what makes the hot-swap contract real: a record validated against
/// model A is scored by model A even if `/reload` publishes model B
/// while it sits in the queue.
struct Pending {
    features: Vec<u32>,
    reply: mpsc::Sender<Result<f32, String>>,
    engine: Arc<InferenceEngine>,
}

struct Queue {
    items: VecDeque<Pending>,
    closed: bool,
}

/// Shared state between submitters and scorer threads.
struct Shared {
    queue: Mutex<Queue>,
    /// Signalled on submit and on close.
    arrived: Condvar,
    cap: usize,
    /// Batches scored / records scored (for `/stats`).
    batches: AtomicU64,
    records: AtomicU64,
}

/// Handle for submitting records; clone freely across worker threads.
#[derive(Clone)]
pub struct MicroBatcher {
    shared: Arc<Shared>,
    max_wait: Duration,
}

/// A scorer-side handle: drains the queue and runs the engine. One per
/// scorer thread (usually one total — the engine call itself can shard
/// across cores).
pub struct Scorer {
    shared: Arc<Shared>,
    max_wait: Duration,
}

impl MicroBatcher {
    /// Build the submit/score pair. `queue_cap` bounds queued records;
    /// `max_wait` is the coalescing budget after the first record of a
    /// batch arrives.
    pub fn new(
        queue_cap: usize,
        max_wait: Duration,
    ) -> (MicroBatcher, Scorer) {
        let shared = Arc::new(Shared {
            queue: Mutex::new(Queue {
                items: VecDeque::new(),
                closed: false,
            }),
            arrived: Condvar::new(),
            cap: queue_cap.max(1),
            batches: AtomicU64::new(0),
            records: AtomicU64::new(0),
        });
        (
            MicroBatcher { shared: Arc::clone(&shared), max_wait },
            Scorer { shared, max_wait },
        )
    }

    /// Enqueue one record against `engine`; returns the channel its
    /// logit (or a scoring error) will arrive on. Errors immediately
    /// when the queue is full (backpressure) or the batcher is shutting
    /// down.
    pub fn submit(
        &self,
        engine: Arc<InferenceEngine>,
        features: Vec<u32>,
    ) -> Result<mpsc::Receiver<Result<f32, String>>, SubmitError> {
        Ok(self
            .submit_many(engine, vec![features])?
            .pop()
            .expect("one receiver per record"))
    }

    /// Enqueue a whole request's records **atomically**: either every
    /// record fits under the queue cap and all are queued, or none are —
    /// a rejected request must not leave orphaned records behind to be
    /// scored with nobody listening. Every record carries the `engine`
    /// it was validated against, so a hot swap mid-queue cannot change
    /// (or invalidate) its score.
    pub fn submit_many(
        &self,
        engine: Arc<InferenceEngine>,
        records: Vec<Vec<u32>>,
    ) -> Result<Vec<mpsc::Receiver<Result<f32, String>>>, SubmitError> {
        let mut receivers = Vec::with_capacity(records.len());
        {
            let mut q = self.shared.queue.lock().unwrap();
            if q.closed {
                return Err(SubmitError::Closed);
            }
            if q.items.len() + records.len() > self.shared.cap {
                return Err(SubmitError::Full(q.items.len()));
            }
            for features in records {
                let (tx, rx) = mpsc::channel();
                q.items.push_back(Pending {
                    features,
                    reply: tx,
                    engine: Arc::clone(&engine),
                });
                receivers.push(rx);
            }
        }
        self.shared.arrived.notify_all();
        Ok(receivers)
    }

    /// Score `features` (one record) end to end: submit, wait for the
    /// scorer, unwrap the reply. `timeout` bounds the wait.
    pub fn score_one(
        &self,
        engine: Arc<InferenceEngine>,
        features: Vec<u32>,
        timeout: Duration,
    ) -> Result<f32> {
        let rx = self.submit(engine, features)?;
        match rx.recv_timeout(timeout + self.max_wait) {
            Ok(Ok(logit)) => Ok(logit),
            Ok(Err(msg)) => bail!("{msg}"),
            Err(_) => bail!("scoring timed out"),
        }
    }

    /// Stop accepting new records and wake the scorer so it drains and
    /// exits. Already-queued records still get scored.
    pub fn close(&self) {
        self.shared.queue.lock().unwrap().closed = true;
        self.shared.arrived.notify_all();
    }

    /// The queue's record capacity — requests larger than this can
    /// never be accepted (the HTTP layer rejects them as client errors
    /// rather than retryable overload).
    pub fn capacity(&self) -> usize {
        self.shared.cap
    }

    pub fn batches_scored(&self) -> u64 {
        self.shared.batches.load(Ordering::Relaxed)
    }

    pub fn records_scored(&self) -> u64 {
        self.shared.records.load(Ordering::Relaxed)
    }
}

impl Scorer {
    /// Scorer loop: runs until [`MicroBatcher::close`] is called and the
    /// queue drains. Each record is scored by the engine it was
    /// submitted against (snapshotted in [`Pending`]), so a hot swap
    /// takes effect for *new* submissions while everything already
    /// queued finishes on the model that accepted it. `engine_of` only
    /// supplies the live batch-size hint for the coalescing wait.
    pub fn run<F>(&self, engine_of: F)
    where
        F: Fn() -> Arc<InferenceEngine>,
    {
        let mut scratch: Option<ScoreScratch> = None;
        loop {
            let cap = engine_of().batch_size();
            let taken = match self.take_batch(cap) {
                Some(t) => t,
                None => return,
            };
            if taken.is_empty() {
                continue;
            }
            let scratch = scratch.get_or_insert_with(|| {
                ScoreScratch::for_engine(&taken[0].engine)
            });
            // group consecutive records that share an engine (pointer
            // identity): across a swap the queue holds a run of old-
            // engine records followed by new-engine ones
            let mut it = taken.into_iter().peekable();
            while let Some(first) = it.next() {
                let engine = Arc::clone(&first.engine);
                let mut group = vec![first];
                while it
                    .peek()
                    .is_some_and(|p| Arc::ptr_eq(&p.engine, &engine))
                {
                    group.push(it.next().expect("peeked"));
                }
                self.score_into(&engine, group, scratch);
            }
        }
    }

    /// Block for the next micro-batch: wait for a first record, then
    /// keep coalescing until `max_batch` records or the wait budget runs
    /// out. `None` once closed and drained.
    fn take_batch(&self, max_batch: usize) -> Option<Vec<Pending>> {
        let mut q = self.shared.queue.lock().unwrap();
        while q.items.is_empty() {
            if q.closed {
                return None;
            }
            let (guard, _) = self
                .shared
                .arrived
                .wait_timeout(q, Duration::from_millis(50))
                .unwrap();
            q = guard;
        }
        // a record is in: coalesce within the wait budget (skipped when
        // the queue already holds a full batch or we're closing)
        let deadline = Instant::now() + self.max_wait;
        loop {
            if q.items.is_empty() || q.closed {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            if q.items.len() >= max_batch {
                break;
            }
            let (guard, timeout) = self
                .shared
                .arrived
                .wait_timeout(q, deadline - now)
                .unwrap();
            q = guard;
            if timeout.timed_out() {
                break;
            }
        }
        if q.items.is_empty() {
            return if q.closed { None } else { Some(Vec::new()) };
        }
        Some(q.items.drain(..).collect())
    }

    /// Score `taken` through `engine` in engine-batch slices, replying
    /// per record. Records whose shape doesn't match the engine get an
    /// error reply; the scorer never dies on bad input.
    fn score_into(
        &self,
        engine: &InferenceEngine,
        taken: Vec<Pending>,
        scratch: &mut ScoreScratch,
    ) {
        if taken.is_empty() {
            return;
        }
        let fields = engine.fields();
        let cap = engine.batch_size();
        let limit = engine.n_features() as u32;
        let mut slice: Vec<Pending> = Vec::with_capacity(cap);
        let mut features: Vec<u32> = Vec::with_capacity(cap * fields);
        let mut flush =
            |slice: &mut Vec<Pending>, features: &mut Vec<u32>| {
                if slice.is_empty() {
                    return;
                }
                let labels = vec![0u8; slice.len()];
                let batch = build_batch(features, &labels, fields, cap);
                let logits = engine.score_with(&batch, scratch);
                self.shared.batches.fetch_add(1, Ordering::Relaxed);
                self.shared
                    .records
                    .fetch_add(slice.len() as u64, Ordering::Relaxed);
                for (p, &z) in slice.drain(..).zip(&logits) {
                    // a dropped receiver (client gone) is fine
                    let _ = p.reply.send(Ok(z));
                }
                features.clear();
            };
        for p in taken {
            // distinct messages per defect so clients can tell a schema
            // mistake (arity) from a hashing mistake (id range); the
            // HTTP layer pre-validates against the same engine, so these
            // only fire for direct MicroBatcher users
            if p.features.len() != fields {
                let _ = p.reply.send(Err(format!(
                    "record holds {} ids, model expects {fields}",
                    p.features.len()
                )));
                continue;
            }
            if let Some(&id) =
                p.features.iter().find(|&&id| id >= limit)
            {
                let _ = p.reply.send(Err(format!(
                    "feature id {id} out of range (table holds {limit} \
                     rows)"
                )));
                continue;
            }
            features.extend_from_slice(&p.features);
            slice.push(p);
            if slice.len() == cap {
                flush(&mut slice, &mut features);
            }
        }
        flush(&mut slice, &mut features);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Experiment, Method, RoundingMode};
    use crate::coordinator::Trainer;
    use crate::data::registry;

    fn tiny_engine() -> Arc<InferenceEngine> {
        let exp = Experiment {
            method: Method::Lpt(RoundingMode::Sr),
            model: "tiny".into(),
            dataset: "synthetic:tiny".into(),
            n_samples: 1200,
            use_runtime: false,
            threads: 1,
            ..Experiment::default()
        };
        let n = registry::schema_for(&exp).unwrap().n_features();
        let mut tr = Trainer::new(exp, n).unwrap();
        let dir = std::env::temp_dir().join("alpt_microbatch_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("micro.ckpt");
        tr.save_checkpoint(&path).unwrap();
        let engine =
            Arc::new(InferenceEngine::from_checkpoint(&path).unwrap());
        std::fs::remove_file(&path).ok();
        engine
    }

    fn record(engine: &InferenceEngine, r: u32) -> Vec<u32> {
        let schema = registry::schema_for(engine.exp()).unwrap();
        (0..engine.fields())
            .map(|f| schema.global_id(f, (r + f as u32) % 5))
            .collect()
    }

    #[test]
    fn coalesced_scores_match_direct_engine_calls() {
        let engine = tiny_engine();
        let (mb, scorer) = MicroBatcher::new(1024, Duration::from_millis(5));
        let eng = Arc::clone(&engine);
        let scorer_thread =
            std::thread::spawn(move || scorer.run(|| Arc::clone(&eng)));

        let n_clients = 8;
        let per_client = 10;
        let results: Vec<Vec<(u32, f32)>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..n_clients)
                .map(|c| {
                    let mb = mb.clone();
                    let engine = Arc::clone(&engine);
                    s.spawn(move || {
                        (0..per_client)
                            .map(|i| {
                                let r = (c * per_client + i) as u32;
                                let z = mb
                                    .score_one(
                                        Arc::clone(&engine),
                                        record(&engine, r),
                                        Duration::from_secs(10),
                                    )
                                    .unwrap();
                                (r, z)
                            })
                            .collect()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        mb.close();
        scorer_thread.join().unwrap();

        assert_eq!(
            mb.records_scored(),
            (n_clients * per_client) as u64
        );
        // micro-batching must coalesce at least some requests
        assert!(
            mb.batches_scored() < mb.records_scored(),
            "batches {} vs records {}",
            mb.batches_scored(),
            mb.records_scored()
        );
        for row in results {
            for (r, z) in row {
                let direct =
                    engine.score_records(&record(&engine, r)).unwrap();
                assert_eq!(
                    z.to_bits(),
                    direct[0].to_bits(),
                    "record {r}: micro-batched logit diverged"
                );
            }
        }
    }

    #[test]
    fn malformed_records_error_without_killing_scorer() {
        let engine = tiny_engine();
        let (mb, scorer) = MicroBatcher::new(64, Duration::from_millis(1));
        let eng = Arc::clone(&engine);
        let t = std::thread::spawn(move || scorer.run(|| Arc::clone(&eng)));
        // wrong arity
        let err = mb
            .score_one(
                Arc::clone(&engine),
                vec![1, 2, 3],
                Duration::from_secs(5),
            )
            .unwrap_err();
        assert!(format!("{err:#}").contains("ids"), "{err:#}");
        // id out of range gets its own message
        let mut bad = record(&engine, 1);
        bad[0] = engine.n_features() as u32;
        let err = mb
            .score_one(Arc::clone(&engine), bad, Duration::from_secs(5))
            .unwrap_err();
        assert!(format!("{err:#}").contains("out of range"), "{err:#}");
        // a valid record still scores afterwards
        let z = mb
            .score_one(
                Arc::clone(&engine),
                record(&engine, 1),
                Duration::from_secs(5),
            )
            .unwrap();
        assert!(z.is_finite());
        mb.close();
        t.join().unwrap();
    }

    #[test]
    fn full_queue_rejects_at_submit() {
        let engine = tiny_engine();
        let (mb, _scorer) = MicroBatcher::new(2, Duration::from_millis(1));
        // no scorer running: the queue fills and the third submit errors
        mb.submit(Arc::clone(&engine), vec![0; 8]).unwrap();
        mb.submit(Arc::clone(&engine), vec![0; 8]).unwrap();
        let err =
            mb.submit(Arc::clone(&engine), vec![0; 8]).unwrap_err();
        assert!(format!("{err:#}").contains("full"));
        mb.close();
    }

    #[test]
    fn close_drains_queued_records() {
        let engine = tiny_engine();
        let (mb, scorer) = MicroBatcher::new(64, Duration::from_millis(1));
        let rx =
            mb.submit(Arc::clone(&engine), record(&engine, 3)).unwrap();
        mb.close();
        // scorer started after close: must still drain the queued record
        let eng = Arc::clone(&engine);
        let t = std::thread::spawn(move || scorer.run(|| Arc::clone(&eng)));
        let got = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(got.unwrap().is_finite());
        t.join().unwrap();
    }
}
