//! The online inference subsystem: one immutable, concurrency-safe
//! [`engine::InferenceEngine`] shared by every scoring entry point
//! (offline batch eval, trainer evaluation, the HTTP server, the serve
//! example), a bounded [`batch::MicroBatcher`] that coalesces concurrent
//! single-record requests into engine batches, and a std-only
//! [`http::Server`] with atomic checkpoint hot-swap
//! ([`http::EngineHandle`]).

pub mod batch;
pub mod engine;
pub mod http;

pub use batch::MicroBatcher;
pub use engine::{score_batch, InferenceEngine, ScoreScratch};
pub use http::{EngineHandle, Server, ServerConfig};
