//! Deterministic pseudo-random number generation.
//!
//! The offline crate set has no `rand`, so the crate carries its own
//! generators: [`SplitMix64`] for seeding/stateless hashing and [`Pcg32`]
//! (PCG-XSH-RR 64/32) as the workhorse stream. Everything that samples —
//! data synthesis, initialization, stochastic rounding, dropout masks —
//! takes an explicit generator, so every experiment is reproducible from
//! its seed.

/// SplitMix64: tiny, solid 64-bit mixer. Used to derive seeds and as a
/// stateless hash for (id, id) interaction weights.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        mix64(self.state)
    }
}

/// Stateless mix of a 64-bit value (SplitMix64 finalizer).
#[inline]
pub fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// PCG-XSH-RR 64/32: small state, good statistical quality, fast.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Seeded constructor; `stream` selects an independent sequence.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Self { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(mix64(seed));
        rng.next_u32();
        rng
    }

    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xDA3E_39CB_94B9_5BDB)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)` with 24 bits of mantissa entropy.
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in `[0, 1)` with 53 bits.
    #[inline]
    pub fn uniform_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform_f32()
    }

    /// Unbiased integer in `[0, n)` (Lemire's method with rejection).
    #[inline]
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u32();
            let m = (x as u64).wrapping_mul(n as u64);
            let lo = m as u32;
            if lo >= n || lo >= (n.wrapping_neg() % n) {
                return (m >> 32) as u32;
            }
        }
    }

    pub fn below_usize(&mut self, n: usize) -> usize {
        debug_assert!(n > 0 && n <= u32::MAX as usize);
        self.below(n as u32) as usize
    }

    /// Standard normal via Box–Muller (pair not cached: branch-free hot use
    /// sites draw in bulk anyway).
    pub fn normal(&mut self) -> f32 {
        let u1 = (1.0 - self.uniform_f64()) as f64; // (0, 1]
        let u2 = self.uniform_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    pub fn normal_scaled(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal()
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f32) -> bool {
        self.uniform_f32() < p
    }

    /// Fill a slice with U[0,1) floats.
    pub fn fill_uniform(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.uniform_f32();
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below_usize(i + 1);
            v.swap(i, j);
        }
    }

    /// Raw generator state `(state, inc)` for checkpointing.
    pub fn state(&self) -> (u64, u64) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from [`Pcg32::state`] output — the restored
    /// generator continues the exact sequence of the saved one.
    pub fn from_state(state: u64, inc: u64) -> Self {
        Self { state, inc }
    }

    /// Counter-based splittable stream: an independent generator that is a
    /// *pure function* of `(seed, step, row)`. Unlike threading one
    /// mutable generator through a row loop, streams built this way can be
    /// drawn from any thread in any order and still reproduce bit-for-bit
    /// — the determinism contract the parallel SR update path relies on.
    pub fn stream_for(seed: u64, step: u64, row: u64) -> Pcg32 {
        StreamKey::for_step(seed, step).row_rng(row)
    }
}

/// Step-level key for counter-based per-row random streams.
///
/// Built once per update step (serially), then split into one independent
/// [`Pcg32`] per row with [`StreamKey::row_rng`]. Each row stream is a
/// pure function of `(key, row)`, so sharding rows across threads cannot
/// change any drawn value: parallel stochastic rounding is bit-identical
/// to the serial order for the same seed, at any thread count.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StreamKey {
    base: u64,
}

impl StreamKey {
    /// Key from an already-mixed per-step value (e.g. one `next_u64` drawn
    /// serially from the trainer's generator).
    pub fn new(base: u64) -> Self {
        Self { base: mix64(base) }
    }

    /// Key from a master seed and a step counter.
    pub fn for_step(seed: u64, step: u64) -> Self {
        Self::new(seed ^ mix64(step.wrapping_mul(0x9E37_79B9_7F4A_7C15)))
    }

    /// Independent generator for `row`. PCG streams are selected by the
    /// increment; distinct rows get distinct (mixed) increments and a
    /// row-mixed starting state.
    #[inline]
    pub fn row_rng(self, row: u64) -> Pcg32 {
        Pcg32::new(self.base ^ mix64(row ^ 0xD6E8_FEB8_6659_FD93), row)
    }
}

/// Zipf(s) sampler over `{0, 1, ..., n-1}` via rejection-inversion
/// (Hörmann & Derflinger), the same algorithm `rand_distr` uses. Heavy
/// head, long tail — the feature-frequency shape CTR datasets exhibit and
/// the property the paper's quantization-sensitivity story depends on.
#[derive(Clone, Debug)]
pub struct Zipf {
    n: f64,
    s: f64,
    h_lo: f64, // H(0.5)
    h_hi: f64, // H(n + 0.5)
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n >= 1, "zipf needs n >= 1");
        assert!(s > 0.0 && (s - 1.0).abs() > 1e-9, "use s != 1");
        let z = Self { n: n as f64, s, h_lo: 0.0, h_hi: 0.0 };
        let h_lo = z.h(0.5);
        let h_hi = z.h(n as f64 + 0.5);
        Self { h_lo, h_hi, ..z }
    }

    /// H(x) = (x^{1-s} - 1) / (1 - s), the antiderivative of x^{-s}.
    #[inline]
    fn h(&self, x: f64) -> f64 {
        (x.powf(1.0 - self.s) - 1.0) / (1.0 - self.s)
    }

    #[inline]
    fn h_inv(&self, y: f64) -> f64 {
        (1.0 + y * (1.0 - self.s)).powf(1.0 / (1.0 - self.s))
    }

    /// Draw a rank in `[0, n)`; rank 0 is the most frequent.
    ///
    /// Rejection-inversion: propose a continuous x with density ∝ x^{-s}
    /// over [0.5, n+0.5] (exact inversion through H), round to integer k,
    /// accept w.p. k^{-s} / (H(k+0.5) - H(k-0.5)). Since x^{-s} is convex,
    /// the bucket integral dominates the midpoint value, so the ratio is
    /// a valid probability and acceptance is high (> 0.85 for s <= 1.5).
    pub fn sample(&self, rng: &mut Pcg32) -> usize {
        loop {
            let u = self.h_lo + rng.uniform_f64() * (self.h_hi - self.h_lo);
            let x = self.h_inv(u);
            let k = x.round().clamp(1.0, self.n);
            let bucket = self.h(k + 0.5) - self.h(k - 0.5);
            let ratio = k.powf(-self.s) / bucket.max(1e-300);
            if rng.uniform_f64() <= ratio {
                return (k as usize) - 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pcg_deterministic() {
        let mut a = Pcg32::seeded(7);
        let mut b = Pcg32::seeded(7);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn pcg_streams_differ() {
        let mut a = Pcg32::new(7, 1);
        let mut b = Pcg32::new(7, 2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Pcg32::seeded(1);
        for _ in 0..10_000 {
            let x = r.uniform_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_close() {
        let mut r = Pcg32::seeded(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_unbiased() {
        let mut r = Pcg32::seeded(11);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(5);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal() as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(9);
        let mut v: Vec<u32> = (0..1000).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..1000).collect::<Vec<u32>>());
        assert_ne!(v[..20], (0..20).collect::<Vec<u32>>()[..]);
    }

    #[test]
    fn stream_for_is_pure_in_its_arguments() {
        let mut a = Pcg32::stream_for(7, 3, 11);
        let mut b = Pcg32::stream_for(7, 3, 11);
        for _ in 0..64 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn stream_for_rows_and_steps_independent() {
        // neighbouring rows / steps must give (near-)uncorrelated streams
        for (s1, t1, r1, s2, t2, r2) in [
            (7, 3, 11, 7, 3, 12),
            (7, 3, 11, 7, 4, 11),
            (7, 3, 11, 8, 3, 11),
            (1, 0, 0, 1, 0, 1),
        ] {
            let mut a = Pcg32::stream_for(s1, t1, r1);
            let mut b = Pcg32::stream_for(s2, t2, r2);
            let same =
                (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
            assert!(same < 4, "streams too similar: {same}/64");
        }
    }

    #[test]
    fn stream_key_draws_are_uniform() {
        // pooled across rows, counter-stream draws must look U[0,1)
        let key = StreamKey::for_step(42, 9);
        let mut sum = 0.0f64;
        let n_rows = 2_000;
        let per_row = 16;
        for row in 0..n_rows {
            let mut r = key.row_rng(row);
            for _ in 0..per_row {
                sum += r.uniform_f32() as f64;
            }
        }
        let mean = sum / (n_rows * per_row) as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn zipf_head_heavier_than_tail() {
        let z = Zipf::new(10_000, 1.1);
        let mut r = Pcg32::seeded(13);
        let mut head = 0usize;
        let mut tail = 0usize;
        for _ in 0..50_000 {
            let k = z.sample(&mut r);
            if k < 10 {
                head += 1;
            }
            if k >= 5_000 {
                tail += 1;
            }
        }
        assert!(head > tail * 3, "head={head} tail={tail}");
        // every draw in range
        for _ in 0..10_000 {
            assert!(z.sample(&mut r) < 10_000);
        }
    }

    #[test]
    fn zipf_rank_zero_most_common() {
        let z = Zipf::new(100, 1.2);
        let mut r = Pcg32::seeded(17);
        let mut counts = vec![0usize; 100];
        for _ in 0..100_000 {
            counts[z.sample(&mut r)] += 1;
        }
        let max_idx = counts
            .iter()
            .enumerate()
            .max_by_key(|(_, c)| **c)
            .unwrap()
            .0;
        assert_eq!(max_idx, 0);
        assert!(counts[0] > counts[10]);
    }
}
