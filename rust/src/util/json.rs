//! Minimal JSON parser + writer (the offline crate set has no `serde`).
//!
//! Covers the full JSON grammar the project touches: the artifact manifest
//! written by `python/compile/aot.py` on the read side, and metrics /
//! experiment-result files on the write side. Numbers are kept as `f64`
//! (the manifest only holds shapes, counts and names — all exact below
//! 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Array(Vec<Json>),
    Object(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing data at byte {}", p.pos);
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    // ------------------------------------------------------------ accessors

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Object(m) => {
                m.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))
            }
            _ => bail!("not an object (want key {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Object(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("not a number"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("not a non-negative integer: {x}");
        }
        Ok(x as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool"),
        }
    }

    pub fn as_array(&self) -> Result<&[Json]> {
        match self {
            Json::Array(v) => Ok(v),
            _ => bail!("not an array"),
        }
    }

    pub fn as_object(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Object(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    pub fn usize_array(&self) -> Result<Vec<usize>> {
        self.as_array()?.iter().map(|v| v.as_usize()).collect()
    }

    // -------------------------------------------------------------- writing

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Array(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Object(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // ------------------------------------------------------------- builders

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Object(
            pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect(),
        )
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Array(v.iter().map(|x| Json::Num(*x)).collect())
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!("expected {:?} at byte {}", b as char, self.pos);
        }
        self.pos += 1;
        Ok(())
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos);
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'n' => self.literal("null", Json::Null),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected {:?} at byte {}", c as char, self.pos),
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Array(items));
                }
                c => bail!("expected , or ] got {:?}", c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            map.insert(key, self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Object(map));
                }
                c => bail!("expected , or }} got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.pos += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let esc = self.peek()?;
                    self.pos += 1;
                    match esc {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)?,
                                16,
                            )?;
                            self.pos += 4;
                            s.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow!("bad codepoint"))?,
                            );
                        }
                        c => bail!("bad escape \\{}", c as char),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                c => {
                    // multi-byte UTF-8: copy the remaining continuation bytes
                    let len = utf8_len(c);
                    let start = self.pos - 1;
                    self.pos = start + len;
                    let chunk = self
                        .bytes
                        .get(start..start + len)
                        .ok_or_else(|| anyhow!("truncated utf8"))?;
                    s.push_str(std::str::from_utf8(chunk)?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek()? == b'-' {
            self.pos += 1;
        }
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos],
                        b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -1.5e2 ").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_array().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_array().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str()
                .unwrap(),
            "c"
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"shape":[256,16],"name":"emb","x":1.5,"ok":true}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("quote\" slash\\ nl\n tab\t".into());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo — ünïcode\"").unwrap();
        assert_eq!(v, Json::Str("héllo — ünïcode".into()));
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn usize_array() {
        let v = Json::parse("[256, 16]").unwrap();
        assert_eq!(v.usize_array().unwrap(), vec![256, 16]);
        assert!(Json::parse("[1.5]").unwrap().usize_array().is_err());
    }
}
