//! Mini property-testing harness (no `proptest` in the offline crate set —
//! DESIGN.md §5.4).
//!
//! A property runs `cases` times against values drawn from a seeded
//! [`Gen`]; on failure the panic message carries the case's seed so the
//! exact counterexample replays with `Gen::from_seed`. No shrinking — the
//! generators are sized small enough that raw counterexamples stay
//! readable.
//!
//! ```no_run
//! // (no_run: rustdoc test binaries don't get the xla rpath on this image)
//! use alpt::util::prop::{check, Gen};
//! check("addition commutes", 100, |g| {
//!     let a = g.f32_in(-1e3, 1e3);
//!     let b = g.f32_in(-1e3, 1e3);
//!     if a + b == b + a { Ok(()) } else { Err(format!("{a} {b}")) }
//! });
//! ```

use super::rng::Pcg32;

/// Value source for properties; thin wrapper over [`Pcg32`] with
/// test-shaped generators.
pub struct Gen {
    rng: Pcg32,
    pub seed: u64,
}

impl Gen {
    pub fn from_seed(seed: u64) -> Self {
        Self { rng: Pcg32::new(seed, 0x9E37), seed }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        lo + self.rng.below_usize(hi - lo + 1)
    }

    pub fn u32_any(&mut self) -> u32 {
        self.rng.next_u32()
    }

    pub fn i32_in(&mut self, lo: i32, hi: i32) -> i32 {
        lo + self.rng.below((hi - lo + 1) as u32) as i32
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform_in(lo, hi)
    }

    pub fn f32_normal(&mut self, std: f32) -> f32 {
        self.rng.normal_scaled(0.0, std)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bernoulli(0.5)
    }

    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn vec_normal(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32_normal(std)).collect()
    }

    pub fn vec_i32(&mut self, n: usize, lo: i32, hi: i32) -> Vec<i32> {
        (0..n).map(|_| self.i32_in(lo, hi)).collect()
    }

    pub fn vec_u32_below(&mut self, n: usize, below: u32) -> Vec<u32> {
        (0..n).map(|_| self.rng.below(below)).collect()
    }

    pub fn pick<'a, T>(&mut self, options: &'a [T]) -> &'a T {
        &options[self.rng.below_usize(options.len())]
    }

    pub fn rng(&mut self) -> &mut Pcg32 {
        &mut self.rng
    }
}

/// Run `property` for `cases` independently-seeded cases; panic with the
/// failing seed + message on the first failure.
pub fn check<F>(name: &str, cases: u64, property: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    check_seeded(name, cases, 0xA17B_5EED, property)
}

/// Like [`check`] with an explicit base seed (replay a failure by passing
/// the reported case seed with `cases = 1 … actually use Gen::from_seed`).
pub fn check_seeded<F>(name: &str, cases: u64, base_seed: u64, property: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base_seed ^ super::rng::mix64(case);
        let mut g = Gen::from_seed(seed);
        if let Err(msg) = property(&mut g) {
            panic!(
                "property {name:?} failed at case {case} (seed \
                 {seed:#018x}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = std::cell::Cell::new(0u64);
        check("counter", 50, |_| {
            count.set(count.get() + 1);
            Ok(())
        });
        let _ = &mut count;
        assert_eq!(count.get(), 50);
    }

    #[test]
    #[should_panic(expected = "property \"always fails\"")]
    fn failing_property_panics_with_seed() {
        check("always fails", 10, |g| {
            let x = g.usize_in(0, 9);
            Err(format!("x={x}"))
        });
    }

    #[test]
    fn gen_ranges_respected() {
        check("ranges", 200, |g| {
            let a = g.usize_in(3, 17);
            if !(3..=17).contains(&a) {
                return Err(format!("usize_in out of range: {a}"));
            }
            let b = g.i32_in(-5, 5);
            if !(-5..=5).contains(&b) {
                return Err(format!("i32_in out of range: {b}"));
            }
            let c = g.f32_in(-2.0, 2.0);
            if !(-2.0..2.0).contains(&c) {
                return Err(format!("f32_in out of range: {c}"));
            }
            Ok(())
        });
    }

    #[test]
    fn same_seed_replays() {
        let mut a = Gen::from_seed(42);
        let mut b = Gen::from_seed(42);
        for _ in 0..20 {
            assert_eq!(a.u32_any(), b.u32_any());
        }
    }
}
