//! Small statistics toolkit: summary stats, percentiles, histograms and a
//! Welford running accumulator. Used by metrics, benches and the analysis
//! module.

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64)
        .sqrt()
}

/// Percentile via linear interpolation on sorted data, `q` in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&sorted, q)
}

/// Percentile on already-sorted data.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = (q / 100.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Welford's online mean/variance accumulator.
#[derive(Clone, Debug, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY,
               max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Fixed-range histogram with uniform bins (used by the Figure-3 bench to
/// plot parameter distributions).
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
    pub below: u64,
    pub above: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Self { lo, hi, counts: vec![0; bins], below: 0, above: 0 }
    }

    pub fn push(&mut self, x: f64) {
        if x < self.lo {
            self.below += 1;
        } else if x >= self.hi {
            self.above += 1;
        } else {
            let bin = ((x - self.lo) / (self.hi - self.lo)
                * self.counts.len() as f64) as usize;
            let last = self.counts.len() - 1;
            self.counts[bin.min(last)] += 1;
        }
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum::<u64>() + self.below + self.above
    }

    /// Terminal sparkline of bin densities (for bench output).
    pub fn sparkline(&self) -> String {
        const LEVELS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let max = self.counts.iter().copied().max().unwrap_or(0).max(1);
        self.counts
            .iter()
            .map(|&c| LEVELS[(c * 7 / max) as usize])
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_known() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn running_matches_batch() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 3.0).collect();
        let mut r = Running::new();
        for &x in &xs {
            r.push(x);
        }
        assert!((r.mean() - mean(&xs)).abs() < 1e-12);
        assert!((r.std() - std(&xs)).abs() < 1e-9);
        assert_eq!(r.count(), 100);
        assert!(r.min() <= r.mean() && r.mean() <= r.max());
    }

    #[test]
    fn histogram_bins() {
        let mut h = Histogram::new(0.0, 1.0, 10);
        for i in 0..100 {
            h.push(i as f64 / 100.0);
        }
        h.push(-0.1);
        h.push(1.5);
        assert_eq!(h.total(), 102);
        assert_eq!(h.below, 1);
        assert_eq!(h.above, 1);
        assert!(h.counts.iter().all(|&c| c == 10));
    }
}
