//! Thread-based parallelism substrate (no `tokio`/`rayon` offline).
//!
//! Two tools: [`ThreadPool`] — a long-lived worker pool fed by an mpsc
//! channel, used by the coordinator's sharded-worker simulation; and
//! [`parallel_chunks`] — scoped fork/join over slices, used for data
//! generation and table-wide operations.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool. Jobs are `FnOnce` closures; `join_idle` blocks
/// until every submitted job has finished.
pub struct ThreadPool {
    sender: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, std::sync::Condvar)>,
}

impl ThreadPool {
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending: Arc<(Mutex<usize>, std::sync::Condvar)> =
            Arc::new((Mutex::new(0), std::sync::Condvar::new()));
        let workers = (0..n)
            .map(|_| {
                let rx = Arc::clone(&rx);
                let pending = Arc::clone(&pending);
                thread::spawn(move || loop {
                    let job = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    match job {
                        Ok(job) => {
                            job();
                            let (lock, cv) = &*pending;
                            let mut p = lock.lock().unwrap();
                            *p -= 1;
                            if *p == 0 {
                                cv.notify_all();
                            }
                        }
                        Err(_) => break,
                    }
                })
            })
            .collect();
        Self { sender: Some(tx), workers, pending }
    }

    /// Submit a job.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        {
            let (lock, _) = &*self.pending;
            *lock.lock().unwrap() += 1;
        }
        self.sender
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker hung up");
    }

    /// Block until all submitted jobs completed.
    pub fn join_idle(&self) {
        let (lock, cv) = &*self.pending;
        let mut p = lock.lock().unwrap();
        while *p > 0 {
            p = cv.wait(p).unwrap();
        }
    }

    pub fn workers(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.sender.take(); // close the channel; workers exit on recv Err
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Split `items` into `n_chunks` contiguous chunks and process them in
/// scoped threads: `f(chunk_index, chunk)`.
pub fn parallel_chunks<T: Send, F>(items: &mut [T], n_chunks: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Send + Sync,
{
    let n_chunks = n_chunks.clamp(1, items.len().max(1));
    let chunk_len = items.len().div_ceil(n_chunks);
    if n_chunks <= 1 || items.len() < 2 {
        f(0, items);
        return;
    }
    thread::scope(|s| {
        for (i, chunk) in items.chunks_mut(chunk_len).enumerate() {
            let f = &f;
            s.spawn(move || f(i, chunk));
        }
    });
}

/// Hardware-derived default worker count (≥ 1).
pub fn default_threads() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Split `0..n` into at most `threads` contiguous ranges of at least
/// `min_per_thread` items and run `f(range)` for each on scoped threads.
/// Degrades to one inline call when a single range remains, so small
/// inputs pay no spawn cost. `f` must produce results that do not depend
/// on which thread (or how many) ran it — the embedding engine guarantees
/// this via counter-based per-row RNG streams.
pub fn parallel_ranges<F>(n: usize, threads: usize, min_per_thread: usize, f: F)
where
    F: Fn(std::ops::Range<usize>) + Send + Sync,
{
    if n == 0 {
        return;
    }
    let max_useful = n.div_ceil(min_per_thread.max(1));
    let threads = threads.max(1).min(max_useful);
    if threads <= 1 {
        f(0..n);
        return;
    }
    let chunk = n.div_ceil(threads);
    thread::scope(|s| {
        let mut lo = 0usize;
        while lo < n {
            let hi = (lo + chunk).min(n);
            let f = &f;
            s.spawn(move || f(lo..hi));
            lo = hi;
        }
    });
}

/// Run `n` indexed tasks on up to `threads` scoped threads, collecting
/// results in index order.
pub fn parallel_map<R: Send, F>(n: usize, threads: usize, f: F) -> Vec<R>
where
    F: Fn(usize) -> R + Send + Sync,
{
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let next = AtomicUsize::new(0);
    let slots = Mutex::new(&mut out);
    thread::scope(|s| {
        for _ in 0..threads.clamp(1, n.max(1)) {
            let f = &f;
            let next = &next;
            let slots = &slots;
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(i);
                slots.lock().unwrap()[i] = Some(r);
            });
        }
    });
    out.into_iter().map(|o| o.unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn pool_reusable_after_join() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for round in 0..3 {
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.submit(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            pool.join_idle();
            assert_eq!(counter.load(Ordering::SeqCst), (round + 1) * 10);
        }
    }

    #[test]
    fn parallel_chunks_touches_everything() {
        let mut v = vec![0u32; 1000];
        parallel_chunks(&mut v, 7, |_, chunk| {
            for x in chunk {
                *x += 1;
            }
        });
        assert!(v.iter().all(|&x| x == 1));
    }

    #[test]
    fn parallel_ranges_covers_each_index_once() {
        for (n, threads, min_per) in
            [(1000, 7, 1), (10, 16, 4), (1, 8, 64), (17, 3, 5), (0, 4, 1)]
        {
            let hits: Vec<AtomicU64> =
                (0..n).map(|_| AtomicU64::new(0)).collect();
            parallel_ranges(n, threads, min_per, |range| {
                for i in range {
                    hits[i].fetch_add(1, Ordering::SeqCst);
                }
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::SeqCst) == 1),
                "n={n} threads={threads}"
            );
        }
    }

    #[test]
    fn default_threads_positive() {
        assert!(default_threads() >= 1);
    }

    #[test]
    fn parallel_map_ordered() {
        let out = parallel_map(50, 8, |i| i * i);
        assert_eq!(out, (0..50).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_single_thread_edge() {
        assert_eq!(parallel_map(3, 1, |i| i), vec![0, 1, 2]);
        assert_eq!(parallel_map(0, 4, |i| i), Vec::<usize>::new());
    }
}
