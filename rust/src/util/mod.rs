//! Infrastructure substrates built in-tree because the build is fully
//! offline (no `rand`, `serde`, `criterion`, `proptest`, `tokio` — see
//! DESIGN.md §5.4).

pub mod bench;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod threadpool;
