//! Micro-benchmark harness (no `criterion` in the offline crate set).
//!
//! `cargo bench` targets are `harness = false` binaries that build a
//! [`Bencher`], time closures with warmup + auto-tuned iteration counts,
//! and print aligned rows (median, mean, p95, throughput). Results can be
//! dumped as JSON for the EXPERIMENTS.md §Perf log.

use std::time::{Duration, Instant};

use super::json::Json;
use super::stats;

/// One measured benchmark.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: u64,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub p95_ns: f64,
    /// Optional work units per iteration (elements, samples, bytes…) for
    /// throughput reporting.
    pub units_per_iter: Option<f64>,
}

impl Measurement {
    pub fn throughput(&self) -> Option<f64> {
        self.units_per_iter.map(|u| u / (self.median_ns * 1e-9))
    }
}

/// Benchmark runner: measures closures and collects rows.
pub struct Bencher {
    pub warmup: Duration,
    pub target: Duration,
    pub samples: usize,
    pub rows: Vec<Measurement>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            target: Duration::from_millis(800),
            samples: 12,
            rows: Vec::new(),
        }
    }

    /// Quick profile for slow end-to-end cases (one sample, tiny warmup).
    pub fn coarse() -> Self {
        Self {
            warmup: Duration::from_millis(0),
            target: Duration::from_millis(1),
            samples: 1,
            rows: Vec::new(),
        }
    }

    /// Measure `f`, recording `units` work items per call for throughput.
    pub fn bench_units<F: FnMut()>(
        &mut self,
        name: &str,
        units: Option<f64>,
        mut f: F,
    ) -> &Measurement {
        // Warmup + estimate per-iteration cost.
        let wstart = Instant::now();
        let mut wcalls = 0u64;
        loop {
            f();
            wcalls += 1;
            if wstart.elapsed() >= self.warmup || wcalls >= 1_000_000 {
                break;
            }
        }
        let per_call = wstart.elapsed().as_secs_f64() / wcalls as f64;
        let iters = ((self.target.as_secs_f64() / self.samples as f64)
            / per_call.max(1e-9))
        .clamp(1.0, 1e8) as u64;

        let mut sample_ns = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            sample_ns.push(t0.elapsed().as_nanos() as f64 / iters as f64);
        }
        sample_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let m = Measurement {
            name: name.to_string(),
            iters,
            median_ns: stats::percentile_sorted(&sample_ns, 50.0),
            mean_ns: stats::mean(&sample_ns),
            p95_ns: stats::percentile_sorted(&sample_ns, 95.0),
            units_per_iter: units,
        };
        println!("{}", format_row(&m));
        self.rows.push(m);
        self.rows.last().unwrap()
    }

    pub fn bench<F: FnMut()>(&mut self, name: &str, f: F) -> &Measurement {
        self.bench_units(name, None, f)
    }

    /// Dump all rows as a JSON array (perf log).
    pub fn to_json(&self) -> Json {
        Json::Array(
            self.rows
                .iter()
                .map(|m| {
                    Json::obj(vec![
                        ("name", Json::str(&m.name)),
                        ("median_ns", Json::num(m.median_ns)),
                        ("mean_ns", Json::num(m.mean_ns)),
                        ("p95_ns", Json::num(m.p95_ns)),
                        ("iters", Json::num(m.iters as f64)),
                        (
                            "units_per_iter",
                            m.units_per_iter
                                .map(Json::num)
                                .unwrap_or(Json::Null),
                        ),
                        (
                            "throughput",
                            m.throughput().map(Json::num).unwrap_or(Json::Null),
                        ),
                    ])
                })
                .collect(),
        )
    }

    /// Write a machine-readable report: `{"schema_version": 1, "meta":
    /// {...}, "benchmarks": [...]}`. This is the cross-PR perf-trajectory
    /// format (`BENCH_micro.json` at the repo root).
    pub fn write_report(
        &self,
        path: &std::path::Path,
        meta: Vec<(&str, Json)>,
    ) -> std::io::Result<()> {
        let doc = Json::obj(vec![
            ("schema_version", Json::num(1.0)),
            ("meta", Json::obj(meta)),
            ("benchmarks", self.to_json()),
        ]);
        std::fs::write(path, doc.to_string())
    }
}

/// Human-readable duration.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:7.1}ns")
    } else if ns < 1e6 {
        format!("{:7.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:7.2}ms", ns / 1e6)
    } else {
        format!("{:7.2}s ", ns / 1e9)
    }
}

/// Human-readable rate.
pub fn fmt_rate(per_sec: f64) -> String {
    if per_sec >= 1e9 {
        format!("{:6.2}G/s", per_sec / 1e9)
    } else if per_sec >= 1e6 {
        format!("{:6.2}M/s", per_sec / 1e6)
    } else if per_sec >= 1e3 {
        format!("{:6.2}K/s", per_sec / 1e3)
    } else {
        format!("{per_sec:6.1}/s")
    }
}

fn format_row(m: &Measurement) -> String {
    let tp = m
        .throughput()
        .map(|t| format!("  {}", fmt_rate(t)))
        .unwrap_or_default();
    format!(
        "  {:<44} {}  (mean {}, p95 {}, n={}){}",
        m.name,
        fmt_ns(m.median_ns),
        fmt_ns(m.mean_ns),
        fmt_ns(m.p95_ns),
        m.iters,
        tp
    )
}

/// Section header for bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bencher {
            warmup: Duration::from_millis(5),
            target: Duration::from_millis(20),
            samples: 3,
            rows: Vec::new(),
        };
        let mut acc = 0u64;
        b.bench_units("noop-ish", Some(16.0), || {
            for i in 0..16u64 {
                acc = acc.wrapping_add(i * i);
            }
        });
        assert_eq!(b.rows.len(), 1);
        let m = &b.rows[0];
        assert!(m.median_ns > 0.0);
        assert!(m.throughput().unwrap() > 0.0);
        std::hint::black_box(acc);
    }

    #[test]
    fn fmt_helpers() {
        assert!(fmt_ns(12.0).contains("ns"));
        assert!(fmt_ns(12e3).contains("µs"));
        assert!(fmt_ns(12e6).contains("ms"));
        assert!(fmt_rate(2e6).contains("M/s"));
    }

    #[test]
    fn json_dump_has_rows() {
        let mut b = Bencher::coarse();
        b.bench("x", || { std::hint::black_box(1 + 1); });
        let j = b.to_json();
        assert_eq!(j.as_array().unwrap().len(), 1);
    }
}
