//! Experiment-grid harness: runs (method × bits × dataset) cells and
//! prints paper-shaped tables. Shared by the `cargo bench` targets that
//! regenerate Tables 1–3 and Figure 4.

use crate::config::{Experiment, Method};
use crate::coordinator::{TrainResult, Trainer};
use crate::data::synthetic::{generate, SyntheticSpec};
use crate::data::Dataset;
use anyhow::{bail, Result};

/// One grid cell's outcome.
#[derive(Clone, Debug)]
pub struct Cell {
    pub dataset: String,
    pub method: String,
    pub bits: u32,
    pub auc: f64,
    pub logloss: f64,
    pub epochs: usize,
    pub secs_per_epoch: f64,
    pub train_comp: f64,
    pub infer_comp: f64,
}

/// Grid scale knobs (env `ALPT_BENCH_QUICK=1` shrinks everything ~6x so
/// CI-style runs stay minutes, not hours).
#[derive(Clone, Debug)]
pub struct GridScale {
    pub samples: usize,
    pub epochs: usize,
    pub patience: usize,
}

impl GridScale {
    pub fn from_env() -> Self {
        if std::env::var("ALPT_BENCH_QUICK").ok().as_deref() == Some("1") {
            Self { samples: 20_000, epochs: 2, patience: 0 }
        } else {
            Self { samples: 60_000, epochs: 4, patience: 2 }
        }
    }
}

/// Dataset-appropriate experiment defaults (paper §4.1, adapted to the
/// SGD-embedding recipe documented in DESIGN.md §5.5).
pub fn base_experiment(dataset: &str, scale: &GridScale) -> Experiment {
    let mut e = Experiment::default().with_dataset_defaults(dataset);
    e.n_samples = scale.samples;
    e.epochs = scale.epochs;
    e.patience = scale.patience;
    e.lr_dense = 1e-3;
    // SGD on embedding rows: calibrated so FP reaches its plateau within
    // the epoch budget on the synthetic workloads
    e.lr_emb = 0.5;
    e.lr_delta = 1e-4;
    e.clip = 0.1;
    if dataset == "tiny" {
        e.n_samples = scale.samples.min(20_000);
    }
    e
}

/// Build (or load) the dataset for an experiment.
pub fn dataset_for(exp: &Experiment) -> Result<Dataset> {
    let spec = match exp.dataset.as_str() {
        "avazu" => SyntheticSpec::avazu(exp.seed),
        "criteo" => SyntheticSpec::criteo(exp.seed),
        "tiny" => SyntheticSpec::tiny(exp.seed),
        other => bail!("unknown dataset {other:?}"),
    };
    let spec = if (exp.vocab_scale - 1.0).abs() > 1e-9 {
        spec.scale_vocabs(exp.vocab_scale)
    } else {
        spec
    };
    Ok(generate(&spec, exp.n_samples))
}

/// Run one cell: train on the split, evaluate on test.
pub fn run_cell(exp: &Experiment, ds: &Dataset, verbose: bool)
    -> Result<Cell> {
    let (train, val, test) = ds.split((0.8, 0.1, 0.1), exp.seed);
    let mut trainer = Trainer::new(exp.clone(), ds.schema.n_features())?;
    let res: TrainResult = trainer.train(&train, &val, verbose)?;
    let ev = trainer.evaluate(&test)?;
    Ok(Cell {
        dataset: exp.dataset.clone(),
        method: res.method.to_string(),
        // the grid sweeps uniform widths; a mixed plan reports its
        // default width in the table
        bits: exp.bits.default_bits(),
        auc: ev.auc,
        logloss: ev.logloss,
        epochs: res.epochs_run,
        secs_per_epoch: res.seconds_per_epoch,
        train_comp: res.train_compression,
        infer_comp: res.infer_compression,
    })
}

/// Print a Table-1 shaped block for one dataset.
pub fn print_table(title: &str, cells: &[Cell]) {
    println!("\n### {title}");
    println!(
        "| {:<10} | {:>6} | {:>7} | {:>8} | {:>13} | {:>8} | {:>8} |",
        "method", "bits", "AUC", "Logloss", "Epochs x Time", "Train-x",
        "Infer-x"
    );
    println!("|{}|", "-".repeat(84));
    for c in cells {
        println!(
            "| {:<10} | {:>6} | {:>7.4} | {:>8.5} | {:>4} x {:>5.1}s \
             | {:>7.1}x | {:>7.1}x |",
            c.method, c.bits, c.auc, c.logloss, c.epochs, c.secs_per_epoch,
            c.train_comp, c.infer_comp
        );
    }
}

/// Persist cells as a JSON file under `results/`.
pub fn save_cells(name: &str, cells: &[Cell]) -> Result<()> {
    use crate::util::json::Json;
    std::fs::create_dir_all("results")?;
    let arr = Json::Array(
        cells
            .iter()
            .map(|c| {
                Json::obj(vec![
                    ("dataset", Json::str(&c.dataset)),
                    ("method", Json::str(&c.method)),
                    ("bits", Json::num(c.bits as f64)),
                    ("auc", Json::num(c.auc)),
                    ("logloss", Json::num(c.logloss)),
                    ("epochs", Json::num(c.epochs as f64)),
                    ("secs_per_epoch", Json::num(c.secs_per_epoch)),
                    ("train_comp", Json::num(c.train_comp)),
                    ("infer_comp", Json::num(c.infer_comp)),
                ])
            })
            .collect(),
    );
    let path = format!("results/{name}.json");
    std::fs::write(&path, arr.to_string())?;
    println!("[saved {path}]");
    Ok(())
}

/// The Table-1 method list at the paper's settings.
pub fn table1_methods() -> Vec<(Method, u32)> {
    use crate::config::RoundingMode::*;
    vec![
        (Method::Fp, 32),
        (Method::Hashing, 32),
        (Method::Pruning, 32),
        (Method::Pact, 8),
        (Method::Lsq, 8),
        (Method::Lpt(Dr), 8),
        (Method::Lpt(Sr), 8),
        (Method::Alpt(Dr), 8),
        (Method::Alpt(Sr), 8),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RoundingMode;

    #[test]
    fn grid_runs_one_tiny_cell() {
        let scale = GridScale { samples: 3000, epochs: 1, patience: 0 };
        let mut exp = base_experiment("tiny", &scale);
        exp.model = "tiny".into();
        exp.method = Method::Alpt(RoundingMode::Sr);
        exp.use_runtime = false;
        let ds = dataset_for(&exp).unwrap();
        let cell = run_cell(&exp, &ds, false).unwrap();
        assert!(cell.auc > 0.4 && cell.auc <= 1.0);
        // tiny model: d=8 -> ALPT ratio = 32/(8+4) ≈ 2.67
        assert!(cell.train_comp > 2.5);
        print_table("smoke", &[cell]);
    }

    #[test]
    fn table1_has_nine_methods() {
        assert_eq!(table1_methods().len(), 9);
    }
}
