//! TOML-subset parser (offline: no `toml` crate).
//!
//! Supported grammar — the subset experiment configs actually use:
//! `key = value` lines, `[section]` headers (flattened to `section.key`),
//! `#` comments, strings, numbers, booleans, and flat arrays. No
//! multi-line strings, no inline tables, no datetimes.

use anyhow::{bail, Result};

/// A parsed TOML value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Num(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

/// Parsed document: ordered `(flattened_key, value)` pairs.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    items: Vec<(String, TomlValue)>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut items = Vec::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let Some(name) = rest.strip_suffix(']') else {
                    bail!("line {}: unterminated section", lineno + 1);
                };
                section = name.trim().to_string();
                continue;
            }
            let Some(eq) = find_top_level_eq(line) else {
                bail!("line {}: expected key = value", lineno + 1);
            };
            let key = line[..eq].trim();
            if key.is_empty() {
                bail!("line {}: empty key", lineno + 1);
            }
            let value = parse_value(line[eq + 1..].trim())
                .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            items.push((full, value));
        }
        Ok(TomlDoc { items })
    }

    pub fn parse_file(path: &std::path::Path) -> Result<TomlDoc> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.items.iter().rev().find(|(k, _)| k == key).map(|(_, v)| v)
    }

    /// All items, flattened keys, in document order.
    pub fn flat_items(&self) -> impl Iterator<Item = (String, TomlValue)> + '_ {
        self.items.iter().cloned()
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }
}

fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn find_top_level_eq(line: &str) -> Option<usize> {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '=' if !in_str => return Some(i),
            _ => {}
        }
    }
    None
}

fn parse_value(text: &str) -> Result<TomlValue> {
    if text.is_empty() {
        bail!("empty value");
    }
    if let Some(stripped) = text.strip_prefix('"') {
        let Some(inner) = stripped.strip_suffix('"') else {
            bail!("unterminated string");
        };
        return Ok(TomlValue::Str(unescape(inner)?));
    }
    if text == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if text == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) =
        text.strip_prefix('[').and_then(|t| t.strip_suffix(']'))
    {
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(TomlValue::Array(vec![]));
        }
        let items = split_top_level(inner)
            .into_iter()
            .map(|piece| parse_value(piece.trim()))
            .collect::<Result<Vec<_>>>()?;
        return Ok(TomlValue::Array(items));
    }
    let cleaned = text.replace('_', "");
    match cleaned.parse::<f64>() {
        Ok(x) => Ok(TomlValue::Num(x)),
        Err(_) => bail!("cannot parse value {text:?}"),
    }
}

fn split_top_level(text: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in text.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                out.push(&text[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&text[start..]);
    out
}

fn unescape(s: &str) -> Result<String> {
    let mut out = String::with_capacity(s.len());
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match chars.next() {
            Some('n') => out.push('\n'),
            Some('t') => out.push('\t'),
            Some('r') => out.push('\r'),
            Some('"') => out.push('"'),
            Some('\\') => out.push('\\'),
            other => bail!("bad escape {other:?}"),
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_flat_keys() {
        let doc = TomlDoc::parse(
            "a = 1\nb = \"two\"\nc = true\nd = [1, 2, 3]\n",
        )
        .unwrap();
        assert_eq!(doc.get("a"), Some(&TomlValue::Num(1.0)));
        assert_eq!(doc.get("b"), Some(&TomlValue::Str("two".into())));
        assert_eq!(doc.get("c"), Some(&TomlValue::Bool(true)));
        assert_eq!(
            doc.get("d"),
            Some(&TomlValue::Array(vec![
                TomlValue::Num(1.0),
                TomlValue::Num(2.0),
                TomlValue::Num(3.0)
            ]))
        );
    }

    #[test]
    fn sections_flatten() {
        let doc =
            TomlDoc::parse("[train]\nlr = 0.1\n[eval]\nlr = 0.2").unwrap();
        assert_eq!(doc.get("train.lr"), Some(&TomlValue::Num(0.1)));
        assert_eq!(doc.get("eval.lr"), Some(&TomlValue::Num(0.2)));
    }

    #[test]
    fn comments_and_underscore_numbers() {
        let doc = TomlDoc::parse(
            "x = 1_000_000 # a million\ns = \"has # inside\" # trailing",
        )
        .unwrap();
        assert_eq!(doc.get("x"), Some(&TomlValue::Num(1e6)));
        assert_eq!(doc.get("s"), Some(&TomlValue::Str("has # inside".into())));
    }

    #[test]
    fn scientific_numbers() {
        let doc = TomlDoc::parse("lr = 2e-5\nneg = -1.5e3").unwrap();
        assert_eq!(doc.get("lr"), Some(&TomlValue::Num(2e-5)));
        assert_eq!(doc.get("neg"), Some(&TomlValue::Num(-1500.0)));
    }

    #[test]
    fn last_duplicate_wins() {
        let doc = TomlDoc::parse("a = 1\na = 2").unwrap();
        assert_eq!(doc.get("a"), Some(&TomlValue::Num(2.0)));
    }

    #[test]
    fn errors_reported_with_line() {
        let err = TomlDoc::parse("ok = 1\nbroken").unwrap_err();
        assert!(err.to_string().contains("line 2"));
        assert!(TomlDoc::parse("x = @@").is_err());
        assert!(TomlDoc::parse("[unterminated").is_err());
    }

    #[test]
    fn string_escapes() {
        let doc = TomlDoc::parse(r#"s = "a\nb\t\"c\"""#).unwrap();
        assert_eq!(doc.get("s"), Some(&TomlValue::Str("a\nb\t\"c\"".into())));
    }
}
