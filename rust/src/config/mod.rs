//! Experiment configuration: typed config structs, a TOML-subset parser
//! for config files, and CLI overrides. This is the "launcher" surface —
//! every example, bench and the `alpt` binary build an [`Experiment`]
//! and hand it to the coordinator.

pub mod toml;

use std::fmt;
use std::str::FromStr;

use anyhow::{bail, ensure, Result};

use crate::quant::{BitWidth, GradScale};
use crate::util::json::Json;
use toml::TomlDoc;

/// Which embedding-compression method to train with (Table 1's rows).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    /// Full-precision embeddings (no compression).
    Fp,
    /// Vanilla low-precision training (Xu et al. 2021), Eq. 8.
    Lpt(RoundingMode),
    /// The paper's contribution: LPT with learned per-feature step sizes.
    Alpt(RoundingMode),
    /// QAT baseline: learned step size, FP master weights (Esser et al.).
    Lsq,
    /// QAT baseline: learned clipping value (Choi et al. 2018).
    Pact,
    /// Quotient–remainder compositional hashing (Shi et al. 2020).
    Hashing,
    /// Magnitude pruning with retraining schedule (Deng et al. 2021).
    Pruning,
}

/// Rounding selection for LPT/ALPT (the paper's SR-vs-DR axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum RoundingMode {
    Sr,
    Dr,
}

impl Method {
    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "fp" => Method::Fp,
            "lpt-sr" | "lpt_sr" | "lpt" => Method::Lpt(RoundingMode::Sr),
            "lpt-dr" | "lpt_dr" => Method::Lpt(RoundingMode::Dr),
            "alpt-sr" | "alpt_sr" | "alpt" => Method::Alpt(RoundingMode::Sr),
            "alpt-dr" | "alpt_dr" => Method::Alpt(RoundingMode::Dr),
            "lsq" => Method::Lsq,
            "pact" => Method::Pact,
            "hashing" | "hash" => Method::Hashing,
            "pruning" | "prune" => Method::Pruning,
            other => bail!("unknown method {other:?}"),
        })
    }

    /// Stable config/CLI token for this method — the inverse of
    /// [`Method::parse`], used by the checkpoint metadata echo.
    pub fn key(&self) -> &'static str {
        match self {
            Method::Fp => "fp",
            Method::Lpt(RoundingMode::Sr) => "lpt-sr",
            Method::Lpt(RoundingMode::Dr) => "lpt-dr",
            Method::Alpt(RoundingMode::Sr) => "alpt-sr",
            Method::Alpt(RoundingMode::Dr) => "alpt-dr",
            Method::Lsq => "lsq",
            Method::Pact => "pact",
            Method::Hashing => "hashing",
            Method::Pruning => "pruning",
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Fp => "FP",
            Method::Lpt(RoundingMode::Sr) => "LPT(SR)",
            Method::Lpt(RoundingMode::Dr) => "LPT(DR)",
            Method::Alpt(RoundingMode::Sr) => "ALPT(SR)",
            Method::Alpt(RoundingMode::Dr) => "ALPT(DR)",
            Method::Lsq => "LSQ",
            Method::Pact => "PACT",
            Method::Hashing => "Hashing",
            Method::Pruning => "Pruning",
        }
    }

    /// Does this method use quantized (integer) table storage at train
    /// time? (Table 1's "training compression" column.)
    pub fn trains_quantized(&self) -> bool {
        matches!(self, Method::Lpt(_) | Method::Alpt(_))
    }
}

/// What a field holds, for precision-plan resolution: Criteo-format
/// files have 13 numeric (bucketized-count) fields followed by 26
/// categorical ones; the synthetic generators are all-categorical.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FieldKind {
    Numeric,
    Categorical,
}

/// One rule's field selector inside a [`PrecisionPlan`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FieldSel {
    /// Every categorical field (`cat:4`).
    Cat,
    /// Every numeric field (`num:8`).
    Num,
    /// One field by index (`f3:2`).
    Field(usize),
}

impl FieldSel {
    fn key(&self) -> String {
        match self {
            FieldSel::Cat => "cat".into(),
            FieldSel::Num => "num".into(),
            FieldSel::Field(i) => format!("f{i}"),
        }
    }
}

/// What a plan assigns to one field: a packed bit width, or one of the
/// *structural* compression kinds (which replace the packed sub-table
/// outright rather than narrowing it).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum GroupKind {
    /// Packed integer codes at this width (2|4|8|16).
    Bits(u32),
    /// Quotient–remainder hashed sub-table (`hash`; Shi et al. 2020).
    Hashed,
    /// Magnitude-pruned dense sub-table (`prune`; Deng et al. 2021).
    Pruned,
}

impl GroupKind {
    /// Stable plan token — the inverse of [`GroupKind::parse`].
    pub fn key(&self) -> String {
        match self {
            GroupKind::Bits(b) => b.to_string(),
            GroupKind::Hashed => "hash".into(),
            GroupKind::Pruned => "prune".into(),
        }
    }

    /// Parse one rule value: a width or a structural token.
    pub fn parse(s: &str) -> Result<GroupKind> {
        Ok(match s.trim().to_ascii_lowercase().as_str() {
            "hash" | "hashed" => GroupKind::Hashed,
            "prune" | "pruned" => GroupKind::Pruned,
            w => {
                let bits = w.parse::<u32>().map_err(|_| {
                    anyhow::anyhow!(
                        "bad plan value {s:?} (expected a bit width or \
                         hash/prune)"
                    )
                })?;
                ensure!(
                    BitWidth::from_bits(bits).is_some(),
                    "unsupported bit width {bits} (expected 2, 4, 8 or 16)"
                );
                GroupKind::Bits(bits)
            }
        })
    }

    /// The packed width, when this kind is one.
    pub fn bits(&self) -> Option<u32> {
        match self {
            GroupKind::Bits(b) => Some(*b),
            _ => None,
        }
    }

    pub fn is_structural(&self) -> bool {
        !matches!(self, GroupKind::Bits(_))
    }
}

/// Per-field embedding precision plan — the `plan` config key / `--plan`
/// flag (`--bits` is the deprecated alias). Fields differ wildly in
/// cardinality and gradient traffic, so they do not all deserve the same
/// precision; a plan assigns each field a bit width — or a structural
/// compression kind — and the embedding layer groups fields of equal
/// assignment into one sub-table each.
///
/// Grammar (comma-separated `selector:value` rules, widths in 2|4|8|16,
/// structural values in `hash`|`prune`):
///
/// * `4` — uniform 4-bit (exactly the pre-plan behaviour);
/// * `cat:4,num:8` — by field kind;
/// * `f3:2,f7:16,default:8` — per-field overrides with a default;
/// * `f0:hash,f3:prune,default:8` — structural kinds per field;
/// * `auto:<bytes>` — not a layout at all but a *budget directive*: the
///   trainer (or `alpt plan`) resolves it into concrete per-field
///   assignments whose inference footprint fits the byte budget.
///
/// Precedence when several rules cover a field: `fN` beats `cat`/`num`
/// beats `default`. Fields no rule names use `default:N` (8 when no
/// default is given; the default must be a width, not a structural
/// kind).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct PrecisionPlan {
    /// Width for fields no rule selects; the whole plan when `rules` is
    /// empty.
    default_bits: u32,
    /// `(selector, kind)` in parse order.
    rules: Vec<(FieldSel, GroupKind)>,
    /// `Some(bytes)` for `auto:<bytes>` budget directives.
    auto_budget: Option<u64>,
}

impl PrecisionPlan {
    /// A uniform plan. Like the pre-plan `bits` field, the width is not
    /// validated here — [`Experiment::bit_width`] / [`PrecisionPlan::parse`]
    /// report unsupported widths.
    pub fn uniform(bits: u32) -> Self {
        Self { default_bits: bits, rules: Vec::new(), auto_budget: None }
    }

    /// A budget directive (`auto:<bytes>`): resolved into concrete
    /// per-field assignments by the planner before any table is built.
    pub fn auto(budget: u64) -> Self {
        Self {
            default_bits: 8,
            rules: Vec::new(),
            auto_budget: Some(budget),
        }
    }

    /// Build a concrete plan from explicit per-field rules (the planner's
    /// output path). The default width backs warm-start surplus rows.
    pub fn from_rules(
        rules: Vec<(FieldSel, GroupKind)>,
        default_bits: u32,
    ) -> Self {
        Self { default_bits, rules, auto_budget: None }
    }

    /// Parse the plan grammar (see the type docs). Every named width is
    /// validated against the supported [`BitWidth`]s.
    pub fn parse(s: &str) -> Result<Self> {
        let s = s.trim();
        ensure!(!s.is_empty(), "empty precision plan");
        if let Some(budget) = s.strip_prefix("auto:") {
            ensure!(
                !budget.contains(','),
                "auto:<bytes> is a whole-plan directive and cannot be \
                 combined with other rules ({s:?})"
            );
            let bytes = parse_byte_budget(budget)?;
            ensure!(bytes > 0, "auto budget must be positive");
            return Ok(Self::auto(bytes));
        }
        if !s.contains(':') {
            let bits = s
                .parse::<u32>()
                .map_err(|_| anyhow::anyhow!("bad bit width {s:?}"))?;
            ensure!(
                BitWidth::from_bits(bits).is_some(),
                "unsupported bit width {bits} (expected 2, 4, 8 or 16)"
            );
            return Ok(Self::uniform(bits));
        }
        let mut default_bits: Option<u32> = None;
        let mut rules = Vec::new();
        for part in s.split(',') {
            let part = part.trim();
            let Some((sel, value)) = part.split_once(':') else {
                bail!(
                    "bad plan rule {part:?} (expected selector:value, e.g. \
                     cat:4 or f0:hash)"
                );
            };
            let kind = GroupKind::parse(value)?;
            let sel = match sel.trim().to_ascii_lowercase().as_str() {
                "default" => {
                    ensure!(
                        default_bits.is_none(),
                        "duplicate default: rule in plan {s:?}"
                    );
                    let Some(bits) = kind.bits() else {
                        bail!(
                            "default must be a bit width, not {:?} — \
                             structural kinds apply to named fields only",
                            kind.key()
                        );
                    };
                    default_bits = Some(bits);
                    continue;
                }
                "cat" => FieldSel::Cat,
                "num" => FieldSel::Num,
                f if f.starts_with('f') => {
                    let idx = f[1..].parse::<usize>().map_err(|_| {
                        anyhow::anyhow!("bad field selector {sel:?}")
                    })?;
                    FieldSel::Field(idx)
                }
                other => bail!(
                    "unknown plan selector {other:?} (expected cat, num, \
                     fN or default)"
                ),
            };
            ensure!(
                !rules.iter().any(|(r, _)| *r == sel),
                "duplicate selector {:?} in plan {s:?}",
                sel.key()
            );
            rules.push((sel, kind));
        }
        Ok(Self {
            default_bits: default_bits.unwrap_or(8),
            rules,
            auto_budget: None,
        })
    }

    /// Stable config/CLI token — the inverse of [`PrecisionPlan::parse`],
    /// used by the checkpoint metadata echo.
    pub fn key(&self) -> String {
        if let Some(budget) = self.auto_budget {
            return format!("auto:{budget}");
        }
        if self.rules.is_empty() {
            return self.default_bits.to_string();
        }
        let mut parts: Vec<String> = self
            .rules
            .iter()
            .map(|(sel, kind)| format!("{}:{}", sel.key(), kind.key()))
            .collect();
        parts.push(format!("default:{}", self.default_bits));
        parts.join(",")
    }

    /// `Some(bits)` when this plan assigns one width to every field.
    pub fn as_uniform(&self) -> Option<u32> {
        (self.rules.is_empty() && self.auto_budget.is_none())
            .then_some(self.default_bits)
    }

    pub fn is_uniform(&self) -> bool {
        self.rules.is_empty() && self.auto_budget.is_none()
    }

    /// `Some(bytes)` for `auto:<bytes>` budget directives — plans the
    /// trainer must resolve into concrete assignments before building a
    /// table.
    pub fn auto_budget(&self) -> Option<u64> {
        self.auto_budget
    }

    /// Does any rule assign a structural kind (hash/prune)?
    pub fn has_structural(&self) -> bool {
        self.rules.iter().any(|(_, k)| k.is_structural())
    }

    /// The fallback width for fields no rule selects (also the width
    /// warm-start surplus rows and the Δ-gradient scale use).
    pub fn default_bits(&self) -> u32 {
        self.default_bits
    }

    /// The width used for batch-level scale factors (the paper's §3.2
    /// gradient scale): the uniform width when the plan is uniform, the
    /// default width otherwise, 8-bit when that width is unsupported.
    pub fn scale_width(&self) -> BitWidth {
        BitWidth::from_bits(self.default_bits).unwrap_or(BitWidth::B8)
    }

    /// The assignment this plan gives `field` of `kind` (precedence:
    /// `fN` > `cat`/`num` > default).
    pub fn kind_for_field(&self, field: usize, kind: FieldKind) -> GroupKind {
        for (sel, k) in &self.rules {
            if *sel == FieldSel::Field(field) {
                return *k;
            }
        }
        for (sel, k) in &self.rules {
            match (sel, kind) {
                (FieldSel::Cat, FieldKind::Categorical)
                | (FieldSel::Num, FieldKind::Numeric) => return *k,
                _ => {}
            }
        }
        GroupKind::Bits(self.default_bits)
    }

    /// The width this plan assigns to `field` of `kind`; structural
    /// assignments fall back to the default width (their sub-tables are
    /// not packed, so the nominal width only labels the group).
    pub fn bits_for_field(&self, field: usize, kind: FieldKind) -> u32 {
        self.kind_for_field(field, kind)
            .bits()
            .unwrap_or(self.default_bits)
    }

    /// Resolve the plan against a concrete field layout: one validated
    /// [`BitWidth`] per field. Errors on `fN` rules past the layout, on
    /// unsupported widths (a hand-built uniform plan can hold one), and
    /// on structural or auto rules — those resolve through
    /// [`PrecisionPlan::resolve_kinds`] / the planner instead.
    pub fn resolve(&self, kinds: &[FieldKind]) -> Result<Vec<BitWidth>> {
        ensure!(
            self.auto_budget.is_none(),
            "plan {:?} is a budget directive; run the planner to resolve \
             it into per-field widths first",
            self.key()
        );
        self.resolve_kinds(kinds)?
            .into_iter()
            .enumerate()
            .map(|(f, k)| match k {
                GroupKind::Bits(bits) => {
                    BitWidth::from_bits(bits).ok_or_else(|| {
                        anyhow::anyhow!("unsupported bit width {bits}")
                    })
                }
                other => bail!(
                    "field f{f} is assigned the structural kind {:?}, \
                     which has no packed bit width",
                    other.key()
                ),
            })
            .collect()
    }

    /// Resolve the plan against a concrete field layout: one
    /// [`GroupKind`] per field (structural kinds allowed). Errors on
    /// `fN` rules past the layout and on auto directives.
    pub fn resolve_kinds(
        &self,
        kinds: &[FieldKind],
    ) -> Result<Vec<GroupKind>> {
        ensure!(
            self.auto_budget.is_none(),
            "plan {:?} is a budget directive; run the planner to resolve \
             it into per-field assignments first",
            self.key()
        );
        for (sel, _) in &self.rules {
            if let FieldSel::Field(i) = sel {
                ensure!(
                    *i < kinds.len(),
                    "plan rule f{i} is out of range for {} fields",
                    kinds.len()
                );
            }
        }
        Ok(kinds
            .iter()
            .enumerate()
            .map(|(f, &kind)| self.kind_for_field(f, kind))
            .collect())
    }

    /// The checkpoint-echo encoding: a JSON number for uniform plans
    /// (byte-identical to the pre-plan `bits` echo) and the plan string
    /// otherwise.
    pub fn echo_json(&self) -> Json {
        match self.as_uniform() {
            Some(bits) => Json::num(bits as f64),
            None => Json::str(&self.key()),
        }
    }

    /// Inverse of [`PrecisionPlan::echo_json`].
    pub fn from_json(v: &Json) -> Result<Self> {
        match v {
            Json::Num(x) => Ok(Self::uniform(*x as u32)),
            Json::Str(s) => Self::parse(s),
            _ => bail!("bits: expected a number or a plan string"),
        }
    }
}

impl fmt::Display for PrecisionPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.key())
    }
}

impl FromStr for PrecisionPlan {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        Self::parse(s)
    }
}

/// Parse an `auto:` byte budget: a plain integer, optionally suffixed
/// `k`/`m`/`g` (binary multiples, case-insensitive). The canonical
/// [`PrecisionPlan::key`] form always prints plain bytes.
pub fn parse_byte_budget(s: &str) -> Result<u64> {
    let s = s.trim().to_ascii_lowercase();
    ensure!(!s.is_empty(), "empty byte budget");
    let (digits, mult) = match s.as_bytes()[s.len() - 1] {
        b'k' => (&s[..s.len() - 1], 1u64 << 10),
        b'm' => (&s[..s.len() - 1], 1u64 << 20),
        b'g' => (&s[..s.len() - 1], 1u64 << 30),
        _ => (&s[..], 1u64),
    };
    let n = digits.trim().parse::<u64>().map_err(|_| {
        anyhow::anyhow!(
            "bad byte budget {s:?} (expected bytes, optionally with a \
             k/m/g suffix)"
        )
    })?;
    n.checked_mul(mult)
        .ok_or_else(|| anyhow::anyhow!("byte budget {s:?} overflows u64"))
}

/// A full training experiment (one Table-1 cell).
#[derive(Clone, Debug)]
pub struct Experiment {
    /// Dataset: "avazu" | "criteo" | "tiny" (synthetic specs), with
    /// optional vocab scale for Table 3.
    pub dataset: String,
    pub vocab_scale: f64,
    pub n_samples: usize,
    /// Manifest model-config name ("avazu", "criteo", "tiny", "*_d32").
    pub model: String,
    pub method: Method,
    /// Embedding precision: a uniform width (`--plan 4`) or a per-field
    /// plan (`--plan cat:4,num:8` / `--plan f3:2,default:8`). Non-uniform
    /// plans build a grouped store with one packed sub-table per width.
    pub bits: PrecisionPlan,
    pub epochs: usize,
    pub seed: u64,

    // paper §4.1 training recipe
    pub lr_dense: f32,
    pub lr_emb: f32,
    pub lr_delta: f32,
    pub wd_emb: f32,
    pub wd_delta: f32,
    pub grad_scale: GradScale,
    /// Fixed clipping value for vanilla LPT (tuned over
    /// {1, 0.1, 0.01, 0.001} in the paper).
    pub clip: f32,
    pub lr_milestones: Vec<usize>,
    pub lr_gamma: f32,
    pub dropout_seed: u64,

    /// Early-stop patience on validation AUC (0 = off).
    pub patience: usize,
    pub artifacts_dir: String,
    /// Execute via the PJRT runtime (true) or the pure-Rust nn path.
    pub use_runtime: bool,
    /// Worker threads for sharded embedding gather/update (0 = one per
    /// hardware thread). Results are bit-identical at any value — the
    /// stores draw SR noise from counter-based per-row streams.
    pub threads: usize,

    // streaming data pipeline (`--dataset criteo:<path>` / `synthetic:*`)
    /// Per-categorical-field hash vocabulary is `2^hash_bits` ids
    /// (file datasets; id 0 = missing).
    pub hash_bits: u32,
    /// Buckets per numeric field after the log transform (file datasets;
    /// includes the missing and negative buckets).
    pub numeric_buckets: u32,
    /// Records buffered by the seeded reservoir shuffle (1 = no shuffle).
    pub shuffle_window: usize,
    /// Batches assembled ahead on the prefetch thread (0 = assemble
    /// serially on the training thread; results are bit-identical).
    pub prefetch_batches: usize,
    /// Streaming runs: checkpoint to the `--save` path every N steps
    /// (0 = only at the end), so `--resume` can continue mid-stream.
    pub save_every: usize,
    /// Continuous checkpointing: fold the delta journal into a fresh
    /// full anchor after this many appended deltas (0 = a library
    /// default; see `Trainer::continuous_save`).
    pub compact_every: usize,
    /// Online re-planning: at every epoch boundary, re-derive a budgeted
    /// plan from the epoch's per-row access counts and migrate rows
    /// between width groups to fit this many inference bytes (0 = off).
    pub replan_budget: usize,
}

impl Default for Experiment {
    fn default() -> Self {
        Self {
            dataset: "tiny".into(),
            vocab_scale: 1.0,
            n_samples: 50_000,
            model: "tiny".into(),
            method: Method::Alpt(RoundingMode::Sr),
            bits: PrecisionPlan::uniform(8),
            epochs: 3,
            seed: 42,
            lr_dense: 1e-3,
            lr_emb: 1e-2,
            lr_delta: 2e-5,
            wd_emb: 5e-8,
            wd_delta: 5e-8,
            grad_scale: GradScale::InvSqrtBdq,
            clip: 0.1,
            lr_milestones: vec![6, 9],
            lr_gamma: 0.1,
            dropout_seed: 1234,
            patience: 2,
            artifacts_dir: "artifacts".into(),
            use_runtime: true,
            threads: 0,
            hash_bits: 16,
            numeric_buckets: 40,
            shuffle_window: 4096,
            prefetch_batches: 2,
            save_every: 0,
            compact_every: 0,
            replan_budget: 0,
        }
    }
}

impl Experiment {
    /// The single bit width of a uniform plan. Errors for mixed plans —
    /// those resolve per field through [`PrecisionPlan::resolve`].
    pub fn bit_width(&self) -> Result<BitWidth> {
        let bits = self.bits.as_uniform().ok_or_else(|| {
            anyhow::anyhow!(
                "precision plan {:?} is not uniform; per-field widths \
                 come from PrecisionPlan::resolve",
                self.bits.key()
            )
        })?;
        BitWidth::from_bits(bits).ok_or_else(|| {
            anyhow::anyhow!("unsupported bit width {bits}")
        })
    }

    /// Load from a TOML document, starting from defaults. A `dataset`
    /// key applies its per-dataset defaults (model, weight decay,
    /// streaming `use_runtime = false`) exactly like `--dataset`, in a
    /// first pass — so every explicit key in the file overrides them no
    /// matter where it appears relative to `dataset`.
    pub fn from_toml(doc: &TomlDoc) -> Result<Experiment> {
        let mut e = Experiment::default();
        for (key, value) in doc.flat_items() {
            if key == "dataset" {
                match &value {
                    toml::TomlValue::Str(s) => {
                        e = e.with_dataset_defaults(s);
                    }
                    _ => bail!("dataset: expected string"),
                }
            }
        }
        for (key, value) in doc.flat_items() {
            if key != "dataset" {
                e.apply(&key, &value)?;
            }
        }
        Ok(e)
    }

    /// Apply a single `key = value` override (also used for CLI flags).
    pub fn apply(&mut self, key: &str, value: &toml::TomlValue) -> Result<()> {
        use toml::TomlValue as V;
        let as_f = |v: &V| -> Result<f64> {
            match v {
                V::Num(x) => Ok(*x),
                V::Str(s) => Ok(s.parse()?),
                _ => bail!("{key}: expected number"),
            }
        };
        let as_s = |v: &V| -> Result<String> {
            match v {
                V::Str(s) => Ok(s.clone()),
                _ => bail!("{key}: expected string"),
            }
        };
        match key {
            "dataset" => self.dataset = as_s(value)?,
            "vocab_scale" => self.vocab_scale = as_f(value)?,
            "n_samples" => self.n_samples = as_f(value)? as usize,
            "model" => self.model = as_s(value)?,
            "method" => self.method = Method::parse(&as_s(value)?)?,
            "bits" | "plan" => {
                self.bits = match value {
                    V::Num(x) => PrecisionPlan::uniform(*x as u32),
                    V::Str(s) => PrecisionPlan::parse(s)?,
                    _ => bail!("{key}: expected a number or a plan string"),
                }
            }
            "epochs" => self.epochs = as_f(value)? as usize,
            "seed" => self.seed = as_f(value)? as u64,
            "lr_dense" => self.lr_dense = as_f(value)? as f32,
            "lr_emb" => self.lr_emb = as_f(value)? as f32,
            "lr_delta" => self.lr_delta = as_f(value)? as f32,
            "wd_emb" => self.wd_emb = as_f(value)? as f32,
            "wd_delta" => self.wd_delta = as_f(value)? as f32,
            "clip" => self.clip = as_f(value)? as f32,
            "lr_gamma" => self.lr_gamma = as_f(value)? as f32,
            "patience" => self.patience = as_f(value)? as usize,
            "threads" => self.threads = as_f(value)? as usize,
            "hash_bits" => self.hash_bits = as_f(value)? as u32,
            "numeric_buckets" => {
                self.numeric_buckets = as_f(value)? as u32
            }
            "shuffle_window" => {
                self.shuffle_window = as_f(value)? as usize
            }
            "prefetch_batches" => {
                self.prefetch_batches = as_f(value)? as usize
            }
            "save_every" => self.save_every = as_f(value)? as usize,
            "compact_every" => {
                self.compact_every = as_f(value)? as usize
            }
            "replan_budget" => {
                self.replan_budget = as_f(value)? as usize
            }
            "dropout_seed" => self.dropout_seed = as_f(value)? as u64,
            "artifacts_dir" => self.artifacts_dir = as_s(value)?,
            "use_runtime" => {
                self.use_runtime = matches!(value, V::Bool(true))
                    || matches!(value, V::Str(s) if s == "true")
            }
            "grad_scale" => {
                self.grad_scale = match as_s(value)?.as_str() {
                    "1" | "one" => GradScale::One,
                    "inv_sqrt_dq" => GradScale::InvSqrtDq,
                    "inv_sqrt_bdq" => GradScale::InvSqrtBdq,
                    other => bail!("unknown grad_scale {other:?}"),
                }
            }
            "lr_milestones" => match value {
                V::Array(items) => {
                    self.lr_milestones = items
                        .iter()
                        .map(|v| as_f(v).map(|x| x as usize))
                        .collect::<Result<_>>()?;
                }
                _ => bail!("lr_milestones: expected array"),
            },
            other => bail!("unknown config key {other:?}"),
        }
        Ok(())
    }

    /// Paper defaults per dataset (§4.1): weight decay and dropout differ
    /// between Avazu and Criteo. Streaming specs (`criteo:<path>`,
    /// `synthetic[:name]`) get the defaults of the generator/format they
    /// wrap, and run host-path-first: no AOT artifacts exist for them,
    /// so the runtime defaults off (a config file can opt back in).
    pub fn with_dataset_defaults(mut self, dataset: &str) -> Self {
        self.dataset = dataset.to_string();
        if dataset.starts_with("criteo:")
            || dataset == "synthetic"
            || dataset.starts_with("synthetic:")
        {
            self.use_runtime = false;
        }
        // `synthetic:NAME` and `criteo:<path>` key the recipe of the
        // generator/format they wrap
        let name = dataset.strip_prefix("synthetic:").unwrap_or(dataset);
        let name =
            if name.starts_with("criteo:") { "criteo" } else { name };
        match name {
            "avazu" => {
                self.wd_emb = 5e-8;
                self.model = "avazu".into();
            }
            "criteo" => {
                self.wd_emb = 1e-5;
                self.model = "criteo".into();
            }
            _ => {}
        }
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_roundtrip() {
        for (s, m) in [
            ("fp", Method::Fp),
            ("lpt-sr", Method::Lpt(RoundingMode::Sr)),
            ("LPT_DR", Method::Lpt(RoundingMode::Dr)),
            ("alpt", Method::Alpt(RoundingMode::Sr)),
            ("lsq", Method::Lsq),
            ("pact", Method::Pact),
            ("hashing", Method::Hashing),
            ("prune", Method::Pruning),
        ] {
            assert_eq!(Method::parse(s).unwrap(), m, "{s}");
        }
        assert!(Method::parse("nope").is_err());
    }

    #[test]
    fn method_key_inverts_parse() {
        for m in [
            Method::Fp,
            Method::Lpt(RoundingMode::Sr),
            Method::Lpt(RoundingMode::Dr),
            Method::Alpt(RoundingMode::Sr),
            Method::Alpt(RoundingMode::Dr),
            Method::Lsq,
            Method::Pact,
            Method::Hashing,
            Method::Pruning,
        ] {
            assert_eq!(Method::parse(m.key()).unwrap(), m);
        }
    }

    #[test]
    fn experiment_from_toml() {
        let doc = TomlDoc::parse(
            r#"
            # Table-1 cell
            dataset = "avazu"
            method = "alpt-sr"
            bits = 4
            epochs = 15
            lr_delta = 2e-5
            lr_milestones = [6, 9]
            use_runtime = true
            "#,
        )
        .unwrap();
        let e = Experiment::from_toml(&doc).unwrap();
        assert_eq!(e.dataset, "avazu");
        assert_eq!(e.method, Method::Alpt(RoundingMode::Sr));
        assert_eq!(e.bits, PrecisionPlan::uniform(4));
        assert_eq!(e.epochs, 15);
        assert_eq!(e.lr_milestones, vec![6, 9]);
        assert!((e.lr_delta - 2e-5).abs() < 1e-12);
    }

    #[test]
    fn unknown_key_rejected() {
        let doc = TomlDoc::parse("nonsense = 1").unwrap();
        assert!(Experiment::from_toml(&doc).is_err());
    }

    #[test]
    fn dataset_defaults() {
        let e = Experiment::default().with_dataset_defaults("criteo");
        assert!((e.wd_emb - 1e-5).abs() < 1e-12);
        assert_eq!(e.model, "criteo");
        assert!(e.use_runtime, "synthetic criteo keeps the runtime default");
        let f = Experiment::default()
            .with_dataset_defaults("criteo:/data/train.tsv");
        assert!((f.wd_emb - 1e-5).abs() < 1e-12);
        assert_eq!(f.model, "criteo");
        assert!(!f.use_runtime, "file pipeline is host-path-first");
        // streaming-synthetic specs key the wrapped generator's recipe
        let s = Experiment::default()
            .with_dataset_defaults("synthetic:criteo");
        assert_eq!(s.model, "criteo");
        assert!((s.wd_emb - 1e-5).abs() < 1e-12);
        assert!(!s.use_runtime);
        let t = Experiment::default().with_dataset_defaults("synthetic");
        assert_eq!(t.model, "tiny");
        assert!(!t.use_runtime);
    }

    #[test]
    fn streaming_keys_from_toml() {
        let doc = TomlDoc::parse(
            r#"
            dataset = "criteo:/data/train.tsv"
            hash_bits = 12
            numeric_buckets = 32
            shuffle_window = 1024
            prefetch_batches = 4
            save_every = 500
            "#,
        )
        .unwrap();
        let e = Experiment::from_toml(&doc).unwrap();
        assert_eq!(e.hash_bits, 12);
        assert_eq!(e.numeric_buckets, 32);
        assert_eq!(e.shuffle_window, 1024);
        assert_eq!(e.prefetch_batches, 4);
        assert_eq!(e.save_every, 500);
        // the dataset key applied its defaults, same as --dataset would
        assert_eq!(e.model, "criteo");
        assert!(!e.use_runtime);
    }

    #[test]
    fn toml_dataset_defaults_never_clobber_explicit_keys() {
        // `model` appears *before* `dataset` in the file; the dataset
        // defaults must still lose to it
        let doc = TomlDoc::parse(
            r#"
            model = "criteo_d32"
            use_runtime = true
            dataset = "criteo:/data/train.tsv"
            "#,
        )
        .unwrap();
        let e = Experiment::from_toml(&doc).unwrap();
        assert_eq!(e.model, "criteo_d32");
        assert!(e.use_runtime, "explicit opt-in must survive");
    }

    #[test]
    fn bit_width_validation() {
        let mut e = Experiment::default();
        e.bits = PrecisionPlan::uniform(8);
        assert!(e.bit_width().is_ok());
        e.bits = PrecisionPlan::uniform(7);
        assert!(e.bit_width().is_err());
        e.bits = PrecisionPlan::parse("cat:4,num:8").unwrap();
        assert!(e.bit_width().is_err(), "mixed plans have no single width");
    }

    #[test]
    fn precision_plan_grammar() {
        // uniform
        let p = PrecisionPlan::parse("4").unwrap();
        assert_eq!(p, PrecisionPlan::uniform(4));
        assert_eq!(p.as_uniform(), Some(4));
        assert_eq!(p.key(), "4");
        // by kind
        let p = PrecisionPlan::parse("cat:4,num:8").unwrap();
        assert!(p.as_uniform().is_none());
        assert_eq!(p.bits_for_field(0, FieldKind::Categorical), 4);
        assert_eq!(p.bits_for_field(0, FieldKind::Numeric), 8);
        assert_eq!(p.key(), "cat:4,num:8,default:8");
        // per-field with default; fN beats kind beats default
        let p = PrecisionPlan::parse("f3:2,cat:16,default:8").unwrap();
        assert_eq!(p.bits_for_field(3, FieldKind::Categorical), 2);
        assert_eq!(p.bits_for_field(1, FieldKind::Categorical), 16);
        assert_eq!(p.bits_for_field(1, FieldKind::Numeric), 8);
        assert_eq!(p.default_bits(), 8);
        // a default-only plan is uniform
        assert_eq!(
            PrecisionPlan::parse("default:2").unwrap(),
            PrecisionPlan::uniform(2)
        );
        // errors: bad widths, bad selectors, duplicates
        assert!(PrecisionPlan::parse("7").is_err());
        assert!(PrecisionPlan::parse("cat:3").is_err());
        assert!(PrecisionPlan::parse("dog:4").is_err());
        assert!(PrecisionPlan::parse("cat:4,cat:8").is_err());
        assert!(PrecisionPlan::parse("default:4,default:8").is_err());
        assert!(PrecisionPlan::parse("fx:4").is_err());
        assert!(PrecisionPlan::parse("").is_err());
    }

    #[test]
    fn precision_plan_structural_rules() {
        let p = PrecisionPlan::parse("f0:hash,f2:prune,default:4").unwrap();
        assert!(p.has_structural());
        assert!(!p.is_uniform());
        assert_eq!(
            p.kind_for_field(0, FieldKind::Categorical),
            GroupKind::Hashed
        );
        assert_eq!(
            p.kind_for_field(2, FieldKind::Categorical),
            GroupKind::Pruned
        );
        assert_eq!(
            p.kind_for_field(1, FieldKind::Categorical),
            GroupKind::Bits(4)
        );
        assert_eq!(p.key(), "f0:hash,f2:prune,default:4");
        // width-only resolution refuses structural fields by name
        let kinds = [FieldKind::Categorical; 3];
        let err = p.resolve(&kinds).unwrap_err();
        assert!(format!("{err:#}").contains("structural"), "{err:#}");
        // kind-aware resolution succeeds
        assert_eq!(
            p.resolve_kinds(&kinds).unwrap(),
            vec![GroupKind::Hashed, GroupKind::Bits(4), GroupKind::Pruned]
        );
        // spelled-out aliases parse to the same kinds
        assert_eq!(
            PrecisionPlan::parse("f0:hashed,f2:pruned,default:4").unwrap(),
            p
        );
        // a structural default is rejected (no width for surplus rows)
        assert!(PrecisionPlan::parse("default:hash").is_err());
        assert!(PrecisionPlan::parse("cat:giraffe").is_err());
    }

    #[test]
    fn precision_plan_auto_budget() {
        let p = PrecisionPlan::parse("auto:1048576").unwrap();
        assert_eq!(p.auto_budget(), Some(1 << 20));
        assert!(!p.is_uniform());
        assert_eq!(p.as_uniform(), None);
        assert_eq!(p.key(), "auto:1048576");
        // k/m/g suffixes normalize to plain bytes
        assert_eq!(
            PrecisionPlan::parse("auto:1m").unwrap().auto_budget(),
            Some(1 << 20)
        );
        assert_eq!(
            PrecisionPlan::parse("auto:64K").unwrap().auto_budget(),
            Some(64 << 10)
        );
        // a directive cannot resolve to widths
        assert!(p.resolve(&[FieldKind::Categorical]).is_err());
        assert!(p.resolve_kinds(&[FieldKind::Categorical]).is_err());
        // echo round-trips through JSON like any other plan string
        assert_eq!(PrecisionPlan::from_json(&p.echo_json()).unwrap(), p);
        // malformed budgets
        assert!(PrecisionPlan::parse("auto:").is_err());
        assert!(PrecisionPlan::parse("auto:0").is_err());
        assert!(PrecisionPlan::parse("auto:12q").is_err());
        assert!(PrecisionPlan::parse("auto:1m,cat:4").is_err());
    }

    #[test]
    fn replan_budget_key_applies() {
        let doc =
            TomlDoc::parse("replan_budget = 4096\nplan = \"cat:4\"").unwrap();
        let e = Experiment::from_toml(&doc).unwrap();
        assert_eq!(e.replan_budget, 4096);
        assert_eq!(e.bits, PrecisionPlan::parse("cat:4").unwrap());
        assert_eq!(Experiment::default().replan_budget, 0);
    }

    #[test]
    fn precision_plan_key_roundtrips() {
        for s in ["8", "2", "cat:4,num:8", "f0:2,f7:16,default:4",
                  "num:16,default:2", "f0:hash,cat:prune,default:8",
                  "auto:4096"] {
            let p = PrecisionPlan::parse(s).unwrap();
            assert_eq!(PrecisionPlan::parse(&p.key()).unwrap(), p, "{s}");
            // FromStr/Display agree with parse/key
            assert_eq!(s.parse::<PrecisionPlan>().unwrap(), p);
            assert_eq!(p.to_string(), p.key());
        }
    }

    #[test]
    fn plan_grammar_roundtrips_for_generated_plans() {
        use crate::util::prop::{check, Gen};
        // any plan the planner can emit — distinct selectors, widths
        // from the supported set, structural kinds on named fields —
        // must survive key() → parse() and Display → FromStr unchanged
        check("plan key/parse roundtrip", 300, |g: &mut Gen| {
            let widths = [2u32, 4, 8, 16];
            let default_bits = *g.pick(&widths);
            let mut pool: Vec<FieldSel> = vec![FieldSel::Cat, FieldSel::Num];
            pool.extend((0..6).map(FieldSel::Field));
            let mut rules = Vec::new();
            for _ in 0..g.usize_in(0, pool.len()) {
                let sel = pool.swap_remove(g.usize_in(0, pool.len() - 1));
                let kind = match g.usize_in(0, 3) {
                    0 => GroupKind::Hashed,
                    1 => GroupKind::Pruned,
                    _ => GroupKind::Bits(*g.pick(&widths)),
                };
                rules.push((sel, kind));
            }
            let plan = PrecisionPlan::from_rules(rules, default_bits);
            let key = plan.key();
            let reparsed = PrecisionPlan::parse(&key)
                .map_err(|e| format!("{key:?} failed to parse: {e}"))?;
            if reparsed != plan {
                return Err(format!(
                    "{key:?} reparsed as {:?}",
                    reparsed.key()
                ));
            }
            let from_str: PrecisionPlan = key
                .parse()
                .map_err(|e| format!("{key:?} FromStr: {e}"))?;
            if from_str != plan {
                return Err(format!("FromStr disagrees on {key:?}"));
            }
            if plan.to_string() != key {
                return Err(format!(
                    "Display {:?} != key {key:?}",
                    plan.to_string()
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn precision_plan_resolve() {
        let kinds = [
            FieldKind::Numeric,
            FieldKind::Numeric,
            FieldKind::Categorical,
        ];
        let p = PrecisionPlan::parse("num:4,f2:16").unwrap();
        let widths = p.resolve(&kinds).unwrap();
        assert_eq!(
            widths,
            vec![BitWidth::B4, BitWidth::B4, BitWidth::B16]
        );
        // out-of-range field rule is an error, not a silent no-op
        let p = PrecisionPlan::parse("f9:4").unwrap();
        assert!(p.resolve(&kinds).is_err());
        // an unsupported uniform width surfaces at resolution too
        assert!(PrecisionPlan::uniform(7).resolve(&kinds).is_err());
    }

    #[test]
    fn precision_plan_echo_json() {
        // uniform plans echo as a JSON number — byte-identical to the
        // pre-plan `bits` echo — and mixed plans as the plan string
        let u = PrecisionPlan::uniform(8);
        assert_eq!(u.echo_json().to_string(), "8");
        assert_eq!(PrecisionPlan::from_json(&u.echo_json()).unwrap(), u);
        let m = PrecisionPlan::parse("cat:4,num:8").unwrap();
        assert_eq!(
            m.echo_json().to_string(),
            "\"cat:4,num:8,default:8\""
        );
        assert_eq!(PrecisionPlan::from_json(&m.echo_json()).unwrap(), m);
    }

    #[test]
    fn bits_plan_from_toml() {
        let doc = TomlDoc::parse(
            r#"
            method = "alpt-sr"
            bits = "cat:4,num:8"
            "#,
        )
        .unwrap();
        let e = Experiment::from_toml(&doc).unwrap();
        assert_eq!(e.bits, PrecisionPlan::parse("cat:4,num:8").unwrap());
        // and a plain number still works
        let doc = TomlDoc::parse("bits = 2").unwrap();
        let e = Experiment::from_toml(&doc).unwrap();
        assert_eq!(e.bits, PrecisionPlan::uniform(2));
    }
}
