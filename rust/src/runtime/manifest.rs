//! The artifact manifest written by `python/compile/aot.py`: model
//! geometries, dense-parameter layouts + init specs, and the artifact
//! file per variant.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::nn::dcn::{DcnConfig, Init};
use crate::util::json::Json;
use crate::util::rng::Pcg32;

/// One dense parameter's spec (flat layout order).
#[derive(Clone, Debug, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: String,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One model config's manifest entry.
#[derive(Clone, Debug)]
pub struct ModelEntry {
    pub name: String,
    pub fields: usize,
    pub emb_dim: usize,
    pub batch: usize,
    pub umax: usize,
    pub cross_depth: usize,
    pub mlp: Vec<usize>,
    pub dropout: f64,
    pub input_dim: usize,
    pub mlp_mask_dim: usize,
    pub n_params: usize,
    pub params: Vec<ParamSpec>,
    pub artifacts: BTreeMap<String, String>,
}

impl ModelEntry {
    /// Initialize the flat dense-parameter vector from the manifest's
    /// per-param init spec (mirrors python/tests init_params).
    pub fn init_params(&self, rng: &mut Pcg32) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.n_params);
        for p in &self.params {
            let n = p.numel();
            match p.init.as_str() {
                "xavier" => {
                    let fan_in = p.shape[0];
                    let fan_out =
                        if p.shape.len() > 1 { p.shape[1] } else { 1 };
                    let a = (6.0 / (fan_in + fan_out) as f32).sqrt();
                    out.extend((0..n).map(|_| rng.uniform_in(-a, a)));
                }
                "normal" => {
                    out.extend((0..n).map(|_| rng.normal_scaled(0.0, 0.01)));
                }
                _ => out.extend(std::iter::repeat(0.0).take(n)),
            }
        }
        debug_assert_eq!(out.len(), self.n_params);
        out
    }

    /// Equivalent Rust-nn config (for the PJRT-free path and tests).
    pub fn dcn_config(&self) -> DcnConfig {
        DcnConfig {
            fields: self.fields,
            emb_dim: self.emb_dim,
            batch: self.batch,
            cross_depth: self.cross_depth,
            mlp: self.mlp.clone(),
        }
    }

    /// Layout check against the Rust-side DcnConfig (paranoid integration
    /// guard: both sides must agree byte-for-byte on the flat layout).
    pub fn layout_matches_rust(&self) -> bool {
        let rust = self.dcn_config().param_layout();
        if rust.len() != self.params.len() {
            return false;
        }
        rust.iter().zip(&self.params).all(|((name, r, c, init), p)| {
            let rust_shape: Vec<usize> = if *c == 1 && p.shape.len() == 1 {
                vec![*r]
            } else {
                vec![*r, *c]
            };
            let init_name = match init {
                Init::Xavier => "xavier",
                Init::Normal => "normal",
                Init::Zero => "zero",
            };
            *name == p.name && rust_shape == p.shape && init_name == p.init
        })
    }
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub configs: BTreeMap<String, ModelEntry>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let json = Json::parse_file(path)?;
        Self::from_json(&json)
            .with_context(|| format!("interpreting {}", path.display()))
    }

    pub fn from_json(json: &Json) -> Result<Manifest> {
        let mut configs = BTreeMap::new();
        for (name, entry) in json.get("configs")?.as_object()? {
            let params = entry
                .get("params")?
                .as_array()?
                .iter()
                .map(|p| {
                    Ok(ParamSpec {
                        name: p.get("name")?.as_str()?.to_string(),
                        shape: p.get("shape")?.usize_array()?,
                        init: p.get("init")?.as_str()?.to_string(),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let artifacts = entry
                .get("artifacts")?
                .as_object()?
                .iter()
                .map(|(k, v)| Ok((k.clone(), v.as_str()?.to_string())))
                .collect::<Result<BTreeMap<_, _>>>()?;
            configs.insert(
                name.clone(),
                ModelEntry {
                    name: name.clone(),
                    fields: entry.get("fields")?.as_usize()?,
                    emb_dim: entry.get("emb_dim")?.as_usize()?,
                    batch: entry.get("batch")?.as_usize()?,
                    umax: entry.get("umax")?.as_usize()?,
                    cross_depth: entry.get("cross_depth")?.as_usize()?,
                    mlp: entry.get("mlp")?.usize_array()?,
                    dropout: entry.get("dropout")?.as_f64()?,
                    input_dim: entry.get("input_dim")?.as_usize()?,
                    mlp_mask_dim: entry.get("mlp_mask_dim")?.as_usize()?,
                    n_params: entry.get("n_params")?.as_usize()?,
                    params,
                    artifacts,
                },
            );
        }
        Ok(Manifest { configs })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "configs": {
        "toy": {
          "fields": 2, "emb_dim": 4, "batch": 8, "umax": 16,
          "cross_depth": 1, "mlp": [8], "dropout": 0.0,
          "input_dim": 8, "mlp_mask_dim": 8, "n_params": 105,
          "params": [
            {"name": "cross_0_w", "shape": [8], "init": "normal"},
            {"name": "cross_0_b", "shape": [8], "init": "zero"},
            {"name": "mlp_0_w", "shape": [8, 8], "init": "xavier"},
            {"name": "mlp_0_b", "shape": [8], "init": "zero"},
            {"name": "final_w", "shape": [16, 1], "init": "xavier"},
            {"name": "final_b", "shape": [1], "init": "zero"}
          ],
          "artifacts": {"train_fp": "toy_train_fp.hlo.txt"}
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::from_json(&Json::parse(SAMPLE).unwrap()).unwrap();
        let e = &m.configs["toy"];
        assert_eq!(e.fields, 2);
        assert_eq!(e.mlp, vec![8]);
        assert_eq!(e.params.len(), 6);
        assert_eq!(e.params[2].numel(), 64);
        assert_eq!(e.artifacts["train_fp"], "toy_train_fp.hlo.txt");
    }

    #[test]
    fn init_params_respects_spec() {
        let m = Manifest::from_json(&Json::parse(SAMPLE).unwrap()).unwrap();
        let e = &m.configs["toy"];
        let mut rng = Pcg32::seeded(1);
        let p = e.init_params(&mut rng);
        assert_eq!(p.len(), 105);
        // cross_0_b (offset 8..16) and final_b (last) are zeros
        assert!(p[8..16].iter().all(|&x| x == 0.0));
        assert_eq!(p[104], 0.0);
        // xavier block is bounded by sqrt(6/16)
        let bound = (6.0f32 / 16.0).sqrt() + 1e-6;
        assert!(p[16..80].iter().all(|&x| x.abs() <= bound));
        // normal block is not all zeros
        assert!(p[0..8].iter().any(|&x| x != 0.0));
    }

    #[test]
    fn layout_matches_rust_side() {
        let m = Manifest::from_json(&Json::parse(SAMPLE).unwrap()).unwrap();
        assert!(m.configs["toy"].layout_matches_rust());
    }

    #[test]
    fn real_manifest_if_present() {
        let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/manifest.json");
        if !path.exists() {
            return;
        }
        let m = Manifest::load(&path).unwrap();
        for (name, entry) in &m.configs {
            assert!(entry.layout_matches_rust(), "layout mismatch in {name}");
            assert_eq!(
                entry.n_params,
                entry.params.iter().map(|p| p.numel()).sum::<usize>(),
                "n_params mismatch in {name}"
            );
            assert_eq!(entry.umax, entry.batch * entry.fields);
        }
    }
}
