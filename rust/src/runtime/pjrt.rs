//! PJRT backend: loads the HLO-text artifacts emitted by
//! `python/compile/aot.py`, compiles them once on the CPU PJRT client, and
//! executes them from the training hot path. Python never runs here.
//!
//! Interchange is HLO *text* (not serialized protos): jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).
//!
//! Compiled only with `--features pjrt`, which requires a vendored `xla`
//! binding crate exposing: `HloModuleProto::from_text_file`,
//! `XlaComputation::from_proto`, `PjRtClient::cpu`/`compile`/
//! `platform_name`, `PjRtLoadedExecutable::execute`, and `Literal`
//! (`vec1`, `reshape`, `scalar`, `to_vec`, `get_first_element`,
//! `to_literal_sync`, `to_tuple`).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};
use xla::{HloModuleProto, PjRtClient, PjRtLoadedExecutable, XlaComputation};

pub use xla::Literal;

use super::{Manifest, ModelEntry};

/// The runtime: one PJRT client + a compile-once executable cache.
pub struct Runtime {
    client: PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: HashMap<String, PjRtLoadedExecutable>,
    /// executions since start (diagnostics)
    pub executions: u64,
}

impl Runtime {
    /// Load the manifest from `dir` (usually `artifacts/`) and start a CPU
    /// PJRT client.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| {
                format!(
                    "loading manifest from {} — run `make artifacts` first",
                    dir.display()
                )
            })?;
        let client = PjRtClient::cpu()
            .map_err(|e| anyhow!("creating PJRT CPU client: {e:?}"))?;
        Ok(Self {
            client,
            dir: dir.to_path_buf(),
            manifest,
            cache: HashMap::new(),
            executions: 0,
        })
    }

    pub fn entry(&self, config: &str) -> Result<&ModelEntry> {
        self.manifest
            .configs
            .get(config)
            .ok_or_else(|| anyhow!("no model config {config:?} in manifest"))
    }

    /// Compile (or fetch from cache) an artifact executable.
    pub fn prepare(&mut self, config: &str, variant: &str) -> Result<()> {
        let key = format!("{config}/{variant}");
        if self.cache.contains_key(&key) {
            return Ok(());
        }
        let entry = self.entry(config)?;
        let fname = entry
            .artifacts
            .get(variant)
            .ok_or_else(|| anyhow!("no variant {variant:?} for {config}"))?
            .clone();
        let path = self.dir.join(&fname);
        let proto = HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {key}: {e:?}"))?;
        self.cache.insert(key, exe);
        Ok(())
    }

    /// Execute `config/variant` with the given inputs; returns the output
    /// tuple elements in manifest order.
    pub fn exec(
        &mut self,
        config: &str,
        variant: &str,
        inputs: &[Literal],
    ) -> Result<Vec<Literal>> {
        self.prepare(config, variant)?;
        let key = format!("{config}/{variant}");
        let exe = self.cache.get(&key).unwrap();
        let result = exe
            .execute::<Literal>(inputs)
            .map_err(|e| anyhow!("executing {key}: {e:?}"))?;
        self.executions += 1;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {key} output: {e:?}"))?;
        // aot.py lowers with return_tuple=True: always a tuple
        out.to_tuple().map_err(|e| anyhow!("untupling {key}: {e:?}"))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

// ---------------------------------------------------------------- literals

/// f32 tensor literal with shape.
pub fn lit_f32(data: &[f32], dims: &[i64]) -> Result<Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape/data mismatch");
    Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

/// i32 tensor literal with shape.
pub fn lit_i32(data: &[i32], dims: &[i64]) -> Result<Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape/data mismatch");
    Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

/// f32 scalar literal.
pub fn lit_scalar(x: f32) -> Literal {
    Literal::scalar(x)
}

/// Extract an f32 vector from a literal.
pub fn to_f32(lit: &Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e:?}"))
}

/// Extract an i32 vector from a literal.
pub fn to_i32(lit: &Literal) -> Result<Vec<i32>> {
    lit.to_vec::<i32>().map_err(|e| anyhow!("to_vec i32: {e:?}"))
}

/// Extract the single f32 from a scalar literal.
pub fn to_scalar_f32(lit: &Literal) -> Result<f32> {
    lit.get_first_element::<f32>()
        .map_err(|e| anyhow!("scalar: {e:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn literal_roundtrip() {
        let l = lit_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(to_f32(&l).unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        let i = lit_i32(&[1, -2, 3], &[3]).unwrap();
        assert_eq!(to_i32(&i).unwrap(), vec![1, -2, 3]);
        assert!(lit_f32(&[1.0], &[2]).is_err());
    }

    #[test]
    fn runtime_loads_and_runs_quantize() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut rt = Runtime::load(&artifacts_dir()).unwrap();
        let entry = rt.entry("tiny").unwrap().clone();
        let (u, d) = (entry.umax, entry.emb_dim);
        let w = vec![0.05f32; u * d];
        let delta = vec![0.01f32; u];
        let noise = vec![0.6f32; u * d];
        let out = rt
            .exec(
                "tiny",
                "quantize",
                &[
                    lit_f32(&w, &[u as i64, d as i64]).unwrap(),
                    lit_f32(&delta, &[u as i64]).unwrap(),
                    lit_f32(&noise, &[u as i64, d as i64]).unwrap(),
                    lit_scalar(-128.0),
                    lit_scalar(127.0),
                ],
            )
            .unwrap();
        assert_eq!(out.len(), 1);
        let codes = to_i32(&out[0]).unwrap();
        // 0.05/0.01 = 5 exactly: SR rounds to 5 regardless of noise
        assert!(codes.iter().all(|&c| c == 5), "codes[0..4]={:?}", &codes[..4]);
        // second exec hits the executable cache
        let _ = rt.exec(
            "tiny",
            "quantize",
            &[
                lit_f32(&w, &[u as i64, d as i64]).unwrap(),
                lit_f32(&delta, &[u as i64]).unwrap(),
                lit_f32(&noise, &[u as i64, d as i64]).unwrap(),
                lit_scalar(-128.0),
                lit_scalar(127.0),
            ],
        );
        assert_eq!(rt.executions, 2);
    }
}
