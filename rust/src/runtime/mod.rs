//! Model-execution runtime.
//!
//! Two interchangeable backends behind one API surface:
//!
//! * [`pjrt`] (feature `pjrt`) — loads the HLO-text artifacts emitted by
//!   `python/compile/aot.py`, compiles them once on the CPU PJRT client,
//!   and executes them from the training hot path. Needs a vendored `xla`
//!   binding crate (see pjrt.rs for the API surface it consumes).
//! * [`host`] (default) — a pure-Rust stand-in: fully functional host
//!   [`Literal`] tensors (so every literal helper works offline), with
//!   `Runtime::load` reporting that HLO execution is unavailable. The
//!   trainer's `use_runtime = false` path and every artifact-gated
//!   test/bench are unaffected.
//!
//! Both export the same names: `Runtime`, `Literal`, `lit_f32`, `lit_i32`,
//! `lit_scalar`, `to_f32`, `to_i32`, `to_scalar_f32`.

pub mod manifest;

pub use manifest::{Manifest, ModelEntry, ParamSpec};

/// Artifact variants exported per model config.
pub const VARIANTS: &[&str] =
    &["train_fp", "train_lpt", "train_fq", "eval_fp", "eval_lpt", "quantize"];

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{
    lit_f32, lit_i32, lit_scalar, to_f32, to_i32, to_scalar_f32, Literal,
    Runtime,
};

#[cfg(not(feature = "pjrt"))]
mod host;
#[cfg(not(feature = "pjrt"))]
pub use host::{
    lit_f32, lit_i32, lit_scalar, to_f32, to_i32, to_scalar_f32, Literal,
    Runtime,
};
