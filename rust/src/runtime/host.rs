//! Host backend (default, no external deps): fully functional host
//! [`Literal`] tensors plus a [`Runtime`] that refuses to load, so any
//! `use_runtime = true` path fails fast with a clear message instead of
//! crashing mid-training. Everything artifact-gated (integration tests,
//! PJRT benches) checks for `artifacts/manifest.json` first and skips.

use std::path::Path;

use anyhow::{anyhow, bail, Result};

use super::{Manifest, ModelEntry};

/// A host-side tensor literal: typed flat data + dims.
#[derive(Clone, Debug, PartialEq)]
pub enum Literal {
    F32 { data: Vec<f32>, dims: Vec<i64> },
    I32 { data: Vec<i32>, dims: Vec<i64> },
}

impl Literal {
    pub fn dims(&self) -> &[i64] {
        match self {
            Literal::F32 { dims, .. } => dims,
            Literal::I32 { dims, .. } => dims,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Literal::F32 { data, .. } => data.len(),
            Literal::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Placeholder runtime: carries the same API as the PJRT backend but
/// `load` always errors (there is no executor to run HLO on).
pub struct Runtime {
    pub manifest: Manifest,
    pub executions: u64,
}

impl Runtime {
    pub fn load(dir: &Path) -> Result<Self> {
        bail!(
            "PJRT runtime unavailable: built without the `pjrt` feature \
             (artifacts in {} cannot be executed; rebuild with \
             --features pjrt and a vendored `xla` crate, or run with \
             use_runtime = false)",
            dir.display()
        )
    }

    pub fn entry(&self, config: &str) -> Result<&ModelEntry> {
        self.manifest
            .configs
            .get(config)
            .ok_or_else(|| anyhow!("no model config {config:?} in manifest"))
    }

    pub fn prepare(&mut self, _config: &str, _variant: &str) -> Result<()> {
        bail!("PJRT runtime unavailable (built without the `pjrt` feature)")
    }

    pub fn exec(
        &mut self,
        config: &str,
        variant: &str,
        _inputs: &[Literal],
    ) -> Result<Vec<Literal>> {
        bail!(
            "cannot execute {config}/{variant}: built without the `pjrt` \
             feature"
        )
    }

    pub fn platform(&self) -> String {
        "host-stub".to_string()
    }
}

// ---------------------------------------------------------------- literals

/// f32 tensor literal with shape.
pub fn lit_f32(data: &[f32], dims: &[i64]) -> Result<Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape/data mismatch");
    Ok(Literal::F32 { data: data.to_vec(), dims: dims.to_vec() })
}

/// i32 tensor literal with shape.
pub fn lit_i32(data: &[i32], dims: &[i64]) -> Result<Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape/data mismatch");
    Ok(Literal::I32 { data: data.to_vec(), dims: dims.to_vec() })
}

/// f32 scalar literal.
pub fn lit_scalar(x: f32) -> Literal {
    Literal::F32 { data: vec![x], dims: vec![] }
}

/// Extract an f32 vector from a literal.
pub fn to_f32(lit: &Literal) -> Result<Vec<f32>> {
    match lit {
        Literal::F32 { data, .. } => Ok(data.clone()),
        Literal::I32 { .. } => bail!("to_vec f32: literal holds i32"),
    }
}

/// Extract an i32 vector from a literal.
pub fn to_i32(lit: &Literal) -> Result<Vec<i32>> {
    match lit {
        Literal::I32 { data, .. } => Ok(data.clone()),
        Literal::F32 { .. } => bail!("to_vec i32: literal holds f32"),
    }
}

/// Extract the single f32 from a scalar literal.
pub fn to_scalar_f32(lit: &Literal) -> Result<f32> {
    match lit {
        Literal::F32 { data, .. } if !data.is_empty() => Ok(data[0]),
        _ => bail!("scalar: empty or non-f32 literal"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = lit_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(to_f32(&l).unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(l.dims(), &[2, 2]);
        let i = lit_i32(&[1, -2, 3], &[3]).unwrap();
        assert_eq!(to_i32(&i).unwrap(), vec![1, -2, 3]);
        assert!(lit_f32(&[1.0], &[2]).is_err());
        assert!(to_i32(&l).is_err());
        assert_eq!(to_scalar_f32(&lit_scalar(2.5)).unwrap(), 2.5);
    }

    #[test]
    fn load_reports_missing_pjrt() {
        let err = Runtime::load(Path::new("artifacts")).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("pjrt"), "{msg}");
    }
}
